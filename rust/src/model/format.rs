//! BKW weight-file formats (mirror of python/compile/train.py).
//!
//! Two wire versions share the tensor-record encoding:
//!
//! ```text
//!     BKW1:  magic b"BKW1", tensor section [, labels section]
//!     BKW2:  magic b"BKW2", spec section, tensor section
//!                           [, labels section]
//!
//!     spec section:
//!         u32le  input_c, input_h, input_w, classes
//!         u32le  n_ops
//!         n_ops * { u8 opcode, fields }
//!             0 = conv2d:   u32le cout, ksize, stride, pad; u8 binarized
//!             1 = maxpool2
//!             2 = batchnorm
//!             3 = sign
//!             4 = flatten
//!             5 = linear:   u32le dout; u8 binarized
//!             6 = scheme:   u32le scheme wire byte (see
//!                           `QuantScheme::wire_byte`) — at most one,
//!                           emitted FIRST and only for non-default
//!                           schemes, so every pre-scheme file (and
//!                           every default-scheme writer) stays
//!                           byte-identical and loads as `sign_sign`
//!
//!     tensor section:
//!         u32le  n_tensors
//!         n_tensors * {
//!             u16le name_len, name (utf-8),
//!             u8 dtype (0 = f32, 1 = u32),
//!             u8 ndim, ndim * u32le dims,
//!             data (little-endian, row-major)
//!         }
//!
//!     labels section (optional, trailing):
//!         magic b"LBLS"
//!         u32le  n_labels         (one per class, in class order)
//!         n_labels * { u16le len, utf-8 bytes }
//! ```
//!
//! BKW2 files carry their own [`NetSpec`], so the engine can serve ANY
//! validated architecture; BKW1 files describe only the legacy CIFAR
//! net and keep loading through [`NetSpec::from_widths`] over their
//! `meta.widths` tensor (u32[9]).  Both store, per weighted layer, the
//! sign-binarized weight tensor (`<layer>.w`) and the folded BN affine
//! (`bn_<layer>.a` / `.b`) under the canonical names of
//! [`NetSpec::layer_names`].
//!
//! The labels section is strictly optional and strictly trailing:
//! readers that stop after the tensor section (BKW1-era tooling, the
//! python `load_bkw`) skip it for free, and files without it serve
//! with numeric class labels.  When present alongside an embedded
//! spec, its entry count must equal the spec's class count.
//!
//! **Two load paths share one parser.**  The parser walks an abstract
//! byte source: [`WeightFile::parse`] streams a reader section by
//! section (tensor payloads decode chunkwise — no whole-file buffer is
//! ever built), and [`WeightFile::open_mmap`] walks a read-only file
//! mapping, in which case tensor payloads *borrow* the mapping
//! ([`WeightTensor::words`] hands out the mapped words zero-copy on
//! little-endian hosts).  Short input on either path is the typed
//! [`FormatError::Truncated`] naming the wire section being decoded
//! and the byte counts involved.
//!
//! Structural failures are typed [`FormatError`]s; the CLI wraps them
//! in `anyhow` context (file path, tensor name) at the boundary.

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::mmap::Mmap;
use super::spec::{LayerSpec, NetSpec, QuantScheme, SpecError};

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 32-bit unsigned integer.
    U32,
}

/// Typed BKW parse/write failures (see the module docs for the wire
/// layout each variant polices).
#[derive(Debug, thiserror::Error)]
pub enum FormatError {
    /// Magic bytes that are neither `BKW1` nor `BKW2`.
    #[error("bad magic {0:?} (expected BKW1 or BKW2)")]
    BadMagic([u8; 4]),
    /// A tensor count past the sanity bound.
    #[error("implausible tensor count {0}")]
    TensorCount(usize),
    /// A tensor name that is not UTF-8.
    #[error("tensor name is not utf-8")]
    BadName,
    /// An unknown dtype byte.
    #[error("unknown dtype {dtype} for tensor '{name}'")]
    UnknownDtype {
        /// Tensor being parsed.
        name: String,
        /// The offending dtype byte.
        dtype: u8,
    },
    /// A rank past the sanity bound.
    #[error("implausible ndim {0}")]
    BadNdim(usize),
    /// An element count past the sanity bound.
    #[error("implausible element count {0}")]
    ElementCount(usize),
    /// An unknown layer opcode in a BKW2 spec section.
    #[error("unknown layer opcode {0} in spec section")]
    BadOpcode(u8),
    /// A scheme op whose wire value names no known quantization
    /// scheme.
    #[error("unknown quantization scheme {0} in spec section")]
    BadScheme(u32),
    /// More than one scheme op in a spec section.
    #[error("duplicate scheme op in spec section")]
    DuplicateScheme,
    /// A spec-section op count past the sanity bound.
    #[error("implausible spec op count {0}")]
    OpCount(usize),
    /// A spec-section dimension (input, classes, or an op field) past
    /// the sanity bound — kept small enough that the IR's shape
    /// arithmetic can never overflow on crafted files.
    #[error("implausible spec dimension {0}")]
    SpecDim(usize),
    /// The embedded spec failed [`NetSpec`] validation.
    #[error("embedded spec is invalid: {0}")]
    Spec(#[from] SpecError),
    /// The input ended inside a wire section: `needed` bytes were
    /// required to finish decoding `section`, only `got` arrived.
    #[error("truncated {section}: needed {needed} bytes, got {got}")]
    Truncated {
        /// The wire section being decoded when the input ran out.
        section: &'static str,
        /// Bytes the current read required.
        needed: usize,
        /// Bytes that actually arrived.
        got: usize,
    },
    /// Underlying I/O failure (truncation is the typed
    /// [`FormatError::Truncated`], not this).
    #[error("i/o: {0}")]
    Io(#[from] std::io::Error),
    /// A lookup for a tensor the file does not contain.
    #[error("missing tensor '{0}'")]
    MissingTensor(String),
    /// A tensor accessed as the wrong dtype.
    #[error("tensor is not {0}")]
    DtypeMismatch(&'static str),
    /// Trailing bytes after the tensor section that are not a labels
    /// section.
    #[error("bad trailing-section magic {0:?} (expected LBLS)")]
    BadLabelMagic([u8; 4]),
    /// A label-count past the sanity bound.
    #[error("implausible label count {0}")]
    LabelCount(usize),
    /// A label that is not UTF-8.
    #[error("label {0} is not utf-8")]
    BadLabel(usize),
    /// A label longer than the u16 wire length field can carry.
    #[error("label {index} is {len} bytes (the wire limit is 65535)")]
    LabelTooLong {
        /// Index of the offending label.
        index: usize,
        /// Its encoded byte length.
        len: usize,
    },
    /// Bytes after the end of the labels section.
    #[error("trailing bytes after the labels section")]
    TrailingBytes,
    /// A labels section whose entry count disagrees with the embedded
    /// spec's class count.
    #[error("labels section has {labels} entries but the spec declares {classes} classes")]
    LabelClassMismatch {
        /// Entries in the labels section.
        labels: usize,
        /// Class count of the embedded spec.
        classes: usize,
    },
}

/// Where a tensor's words live: on the heap (streamed parse,
/// in-memory assembly) or inside a shared file mapping (zero-copy —
/// the `open_mmap` path).
#[derive(Debug, Clone)]
enum TensorWords {
    Owned(Vec<u32>),
    Mapped {
        map: Arc<Mmap>,
        byte_off: usize,
        words: usize,
    },
}

/// One named tensor from a BKW file.
#[derive(Debug, Clone)]
pub struct WeightTensor {
    /// Element type.
    pub dtype: Dtype,
    /// Dimension sizes.
    pub shape: Vec<usize>,
    words: TensorWords,
}

impl WeightTensor {
    /// Assemble a tensor from heap-owned little-endian words
    /// (reinterpreted per `dtype`).  The word count must equal the
    /// shape's element count.
    pub fn owned(dtype: Dtype, shape: Vec<usize>, words: Vec<u32>) -> Self {
        assert_eq!(
            words.len(),
            shape.iter().product::<usize>(),
            "word count must match the shape's element count"
        );
        Self { dtype, shape, words: TensorWords::Owned(words) }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the words live in a file mapping (the
    /// [`WeightFile::open_mmap`] path) rather than on the heap.
    pub fn is_mapped(&self) -> bool {
        matches!(self.words, TensorWords::Mapped { .. })
    }

    /// The raw little-endian words.  Owned tensors borrow their heap
    /// buffer; mapped tensors borrow the file mapping directly when
    /// the platform allows it (little-endian target, 4-byte-aligned
    /// payload — the common case) and otherwise decode into a fresh
    /// vector.
    pub fn words(&self) -> Cow<'_, [u32]> {
        match &self.words {
            TensorWords::Owned(v) => Cow::Borrowed(v),
            TensorWords::Mapped { map, byte_off, words } => {
                let bytes =
                    &map.as_slice()[*byte_off..*byte_off + words * 4];
                if cfg!(target_endian = "little")
                    && bytes.as_ptr().align_offset(4) == 0
                {
                    // SAFETY: the range is in bounds, 4-byte aligned,
                    // u32 has no invalid bit patterns, and the mapping
                    // is immutable for the borrow's lifetime.
                    Cow::Borrowed(unsafe {
                        std::slice::from_raw_parts(
                            bytes.as_ptr().cast::<u32>(),
                            *words,
                        )
                    })
                } else {
                    Cow::Owned(
                        bytes
                            .chunks_exact(4)
                            .map(|c| {
                                u32::from_le_bytes([c[0], c[1], c[2], c[3]])
                            })
                            .collect(),
                    )
                }
            }
        }
    }

    /// The elements as f32 (errors on non-f32 tensors).
    pub fn as_f32(&self) -> Result<Vec<f32>, FormatError> {
        if self.dtype != Dtype::F32 {
            return Err(FormatError::DtypeMismatch("f32"));
        }
        Ok(self.words().iter().map(|&w| f32::from_bits(w)).collect())
    }

    /// The raw words of a u32 tensor (errors on non-u32 tensors).
    /// Borrowed zero-copy where storage allows — see
    /// [`WeightTensor::words`].
    pub fn as_u32(&self) -> Result<Cow<'_, [u32]>, FormatError> {
        if self.dtype != Dtype::U32 {
            return Err(FormatError::DtypeMismatch("u32"));
        }
        Ok(self.words())
    }
}

/// A parsed BKW1/BKW2 file.
#[derive(Debug, Clone)]
pub struct WeightFile {
    tensors: BTreeMap<String, WeightTensor>,
    /// The embedded architecture (BKW2 only).
    spec: Option<NetSpec>,
    /// The optional class-label table (trailing labels section).
    labels: Option<Vec<String>>,
}

// ---------------------------------------------------------------------------
// Byte sources: one parser body, two storage strategies
// ---------------------------------------------------------------------------

/// The byte source the parser walks: a streaming reader
/// ([`WeightFile::parse`]) or an mmap'd range
/// ([`WeightFile::open_mmap`]).  Each source tracks the wire section
/// currently being decoded so short input surfaces as
/// [`FormatError::Truncated`] naming it.
trait ByteSource {
    /// Label subsequent reads as decoding `section`.
    fn enter(&mut self, section: &'static str);

    /// Read exactly `n` bytes (small fixed-size fields).
    fn take(&mut self, n: usize) -> Result<Vec<u8>, FormatError>;

    /// Consume `words * 4` bytes of tensor payload as word storage —
    /// owned for streams, borrowed from the map for mmap.
    fn payload(&mut self, words: usize) -> Result<TensorWords, FormatError>;

    /// Read 4 magic bytes, or `None` on clean EOF at a section
    /// boundary (a partial magic is [`FormatError::Truncated`]).
    fn magic4(&mut self) -> Result<Option<[u8; 4]>, FormatError>;

    /// Error with [`FormatError::TrailingBytes`] unless the source is
    /// exhausted.
    fn expect_end(&mut self) -> Result<(), FormatError>;
}

/// Streaming source over any reader; decodes section by section with a
/// bounded chunk buffer (no whole-file allocation).
struct StreamSource<R: Read> {
    r: R,
    section: &'static str,
}

impl<R: Read> StreamSource<R> {
    fn new(r: R) -> Self {
        Self { r, section: "magic" }
    }

    /// `read_exact` with byte accounting: EOF mid-field becomes the
    /// typed truncation error instead of a generic short-read.
    fn fill(&mut self, buf: &mut [u8], needed: usize, already: usize)
            -> Result<(), FormatError> {
        let mut got = 0;
        while got < buf.len() {
            match self.r.read(&mut buf[got..]) {
                Ok(0) => {
                    return Err(FormatError::Truncated {
                        section: self.section,
                        needed,
                        got: already + got,
                    })
                }
                Ok(k) => got += k,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(FormatError::Io(e)),
            }
        }
        Ok(())
    }
}

impl<R: Read> ByteSource for StreamSource<R> {
    fn enter(&mut self, section: &'static str) {
        self.section = section;
    }

    fn take(&mut self, n: usize) -> Result<Vec<u8>, FormatError> {
        let mut buf = vec![0u8; n];
        self.fill_owned(&mut buf, n)?;
        Ok(buf)
    }

    fn payload(&mut self, words: usize) -> Result<TensorWords, FormatError> {
        // Decode chunkwise straight into the word vector: the peak
        // transient is one chunk, not a second full-size byte buffer.
        let needed = words * 4;
        let mut out = Vec::with_capacity(words);
        let mut chunk = [0u8; 16 * 1024];
        let mut done = 0usize;
        while done < needed {
            let want = (needed - done).min(chunk.len());
            self.fill(&mut chunk[..want], needed, done)?;
            out.extend(chunk[..want].chunks_exact(4).map(|c| {
                u32::from_le_bytes([c[0], c[1], c[2], c[3]])
            }));
            done += want;
        }
        Ok(TensorWords::Owned(out))
    }

    fn magic4(&mut self) -> Result<Option<[u8; 4]>, FormatError> {
        // A zero-byte first read is clean EOF (no trailing section);
        // a partial magic is truncation.
        let mut magic = [0u8; 4];
        let first = loop {
            match self.r.read(&mut magic) {
                Ok(n) => break n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(FormatError::Io(e)),
            }
        };
        if first == 0 {
            return Ok(None);
        }
        if first < 4 {
            self.fill_owned(&mut magic[first..], 4)
                .map_err(|e| match e {
                    FormatError::Truncated { section, got, .. } => {
                        FormatError::Truncated {
                            section,
                            needed: 4,
                            got: first + got,
                        }
                    }
                    other => other,
                })?;
        }
        Ok(Some(magic))
    }

    fn expect_end(&mut self) -> Result<(), FormatError> {
        let mut probe = [0u8; 1];
        loop {
            match self.r.read(&mut probe) {
                Ok(0) => return Ok(()),
                Ok(_) => return Err(FormatError::TrailingBytes),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(FormatError::Io(e)),
            }
        }
    }
}

impl<R: Read> StreamSource<R> {
    /// [`StreamSource::fill`] for reads starting a fresh field.
    fn fill_owned(&mut self, buf: &mut [u8], needed: usize)
                  -> Result<(), FormatError> {
        self.fill(buf, needed, 0)
    }
}

/// Source over a shared file mapping; tensor payloads are recorded as
/// (offset, length) references into it — zero copy.
struct MapSource {
    map: Arc<Mmap>,
    pos: usize,
    section: &'static str,
}

impl MapSource {
    fn new(map: Arc<Mmap>) -> Self {
        Self { map, pos: 0, section: "magic" }
    }

    fn remaining(&self) -> usize {
        self.map.len() - self.pos
    }

    fn advance(&mut self, n: usize) -> Result<usize, FormatError> {
        if self.remaining() < n {
            return Err(FormatError::Truncated {
                section: self.section,
                needed: n,
                got: self.remaining(),
            });
        }
        let at = self.pos;
        self.pos += n;
        Ok(at)
    }
}

impl ByteSource for MapSource {
    fn enter(&mut self, section: &'static str) {
        self.section = section;
    }

    fn take(&mut self, n: usize) -> Result<Vec<u8>, FormatError> {
        let at = self.advance(n)?;
        Ok(self.map.as_slice()[at..at + n].to_vec())
    }

    fn payload(&mut self, words: usize) -> Result<TensorWords, FormatError> {
        let at = self.advance(words * 4)?;
        Ok(TensorWords::Mapped {
            map: Arc::clone(&self.map),
            byte_off: at,
            words,
        })
    }

    fn magic4(&mut self) -> Result<Option<[u8; 4]>, FormatError> {
        if self.remaining() == 0 {
            return Ok(None);
        }
        let b = self.take(4)?;
        Ok(Some([b[0], b[1], b[2], b[3]]))
    }

    fn expect_end(&mut self) -> Result<(), FormatError> {
        if self.remaining() != 0 {
            return Err(FormatError::TrailingBytes);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Shared parser body
// ---------------------------------------------------------------------------

fn read_u16(s: &mut impl ByteSource) -> Result<u16, FormatError> {
    let b = s.take(2)?;
    Ok(u16::from_le_bytes([b[0], b[1]]))
}

fn read_u32(s: &mut impl ByteSource) -> Result<u32, FormatError> {
    let b = s.take(4)?;
    Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
}

fn read_u8(s: &mut impl ByteSource) -> Result<u8, FormatError> {
    Ok(s.take(1)?[0])
}

/// BKW2 layer opcodes (shared with python/compile/train.py).
const OP_CONV2D: u8 = 0;
const OP_MAXPOOL2: u8 = 1;
const OP_BATCHNORM: u8 = 2;
const OP_SIGN: u8 = 3;
const OP_FLATTEN: u8 = 4;
const OP_LINEAR: u8 = 5;
const OP_SCHEME: u8 = 6;

/// Sanity bound on every spec-section dimension: generous for real
/// nets, small enough that validation's shape products (`c*h*w`,
/// `cin*k*k`, ...) stay far from usize overflow on crafted files.
const MAX_SPEC_DIM: usize = 1 << 20;

fn read_dim(s: &mut impl ByteSource) -> Result<usize, FormatError> {
    let v = read_u32(s)? as usize;
    if v > MAX_SPEC_DIM {
        return Err(FormatError::SpecDim(v));
    }
    Ok(v)
}

fn read_spec(s: &mut impl ByteSource) -> Result<NetSpec, FormatError> {
    let c = read_dim(s)?;
    let h = read_dim(s)?;
    let w = read_dim(s)?;
    let classes = read_dim(s)?;
    let n_ops = read_u32(s)? as usize;
    if n_ops > 10_000 {
        return Err(FormatError::OpCount(n_ops));
    }
    let mut layers = Vec::with_capacity(n_ops);
    let mut scheme: Option<QuantScheme> = None;
    for _ in 0..n_ops {
        let opcode = read_u8(s)?;
        if opcode == OP_SCHEME {
            let v = read_u32(s)?;
            let parsed = u8::try_from(v)
                .ok()
                .and_then(QuantScheme::from_wire_byte)
                .ok_or(FormatError::BadScheme(v))?;
            if scheme.replace(parsed).is_some() {
                return Err(FormatError::DuplicateScheme);
            }
            continue;
        }
        layers.push(match opcode {
            OP_CONV2D => {
                let cout = read_dim(s)?;
                let ksize = read_dim(s)?;
                let stride = read_dim(s)?;
                let pad = read_dim(s)?;
                let binarized = read_u8(s)? != 0;
                LayerSpec::Conv2d { cout, ksize, stride, pad, binarized }
            }
            OP_MAXPOOL2 => LayerSpec::MaxPool2,
            OP_BATCHNORM => LayerSpec::BatchNorm,
            OP_SIGN => LayerSpec::Sign,
            OP_FLATTEN => LayerSpec::Flatten,
            OP_LINEAR => {
                let dout = read_dim(s)?;
                let binarized = read_u8(s)? != 0;
                LayerSpec::Linear { dout, binarized }
            }
            other => return Err(FormatError::BadOpcode(other)),
        });
    }
    Ok(NetSpec::with_classes_scheme(
        (c, h, w),
        classes,
        layers,
        scheme.unwrap_or_default(),
    )?)
}

/// Magic of the optional trailing labels section.
const LABELS_MAGIC: &[u8; 4] = b"LBLS";

/// Sanity bound on the label-table entry count (a class count far past
/// any real classifier, small enough to reject corrupt counts).
const MAX_LABELS: usize = 1 << 16;

/// After the tensor section: EOF means no labels; anything else must
/// be a complete `LBLS` section ending the file.
fn read_labels(s: &mut impl ByteSource)
               -> Result<Option<Vec<String>>, FormatError> {
    s.enter("labels section");
    let Some(magic) = s.magic4()? else {
        return Ok(None);
    };
    if &magic != LABELS_MAGIC {
        return Err(FormatError::BadLabelMagic(magic));
    }
    let n = read_u32(s)? as usize;
    if n > MAX_LABELS {
        return Err(FormatError::LabelCount(n));
    }
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let len = read_u16(s)? as usize;
        let bytes = s.take(len)?;
        labels.push(String::from_utf8(bytes)
            .map_err(|_| FormatError::BadLabel(i))?);
    }
    // The labels section is the file's last: anything after it is
    // corruption.
    s.expect_end()?;
    Ok(Some(labels))
}

fn parse_from(s: &mut impl ByteSource) -> Result<WeightFile, FormatError> {
    s.enter("magic");
    let magic = s.take(4)?;
    let spec = match &magic[..] {
        b"BKW1" => None,
        b"BKW2" => {
            s.enter("spec section");
            Some(read_spec(s)?)
        }
        _ => {
            return Err(FormatError::BadMagic([
                magic[0], magic[1], magic[2], magic[3],
            ]))
        }
    };
    s.enter("tensor table");
    let n = read_u32(s)? as usize;
    if n >= 100_000 {
        return Err(FormatError::TensorCount(n));
    }
    let mut tensors = BTreeMap::new();
    for _ in 0..n {
        s.enter("tensor header");
        let name_len = read_u16(s)? as usize;
        let name = String::from_utf8(s.take(name_len)?)
            .map_err(|_| FormatError::BadName)?;
        let dt = read_u8(s)?;
        let dtype = match dt {
            0 => Dtype::F32,
            1 => Dtype::U32,
            _ => {
                return Err(FormatError::UnknownDtype { name, dtype: dt })
            }
        };
        let ndim = read_u8(s)? as usize;
        if ndim > 8 {
            return Err(FormatError::BadNdim(ndim));
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(s)? as usize);
        }
        let count: usize = shape.iter().product();
        if count >= 1 << 28 {
            return Err(FormatError::ElementCount(count));
        }
        s.enter("tensor data");
        let words = s.payload(count)?;
        tensors.insert(name, WeightTensor { dtype, shape, words });
    }
    let labels = read_labels(s)?;
    if let (Some(labels), Some(spec)) = (&labels, &spec) {
        if labels.len() != spec.classes() {
            return Err(FormatError::LabelClassMismatch {
                labels: labels.len(),
                classes: spec.classes(),
            });
        }
    }
    Ok(WeightFile { tensors, spec, labels })
}

fn write_labels(w: &mut impl Write, labels: &[String])
                -> Result<(), FormatError> {
    // Enforce the wire limits the reader polices, so a writable table
    // is always a re-parsable one (no silent `as u16`/`as u32`
    // truncation producing a corrupt trailer).
    if labels.len() > MAX_LABELS {
        return Err(FormatError::LabelCount(labels.len()));
    }
    w.write_all(LABELS_MAGIC)?;
    w.write_all(&(labels.len() as u32).to_le_bytes())?;
    for (index, label) in labels.iter().enumerate() {
        let lb = label.as_bytes();
        let len: u16 = lb.len().try_into().map_err(|_| {
            FormatError::LabelTooLong { index, len: lb.len() }
        })?;
        w.write_all(&len.to_le_bytes())?;
        w.write_all(lb)?;
    }
    Ok(())
}

fn write_spec(w: &mut impl Write, spec: &NetSpec)
              -> Result<(), FormatError> {
    let (ic, ih, iw) = spec.input();
    // Non-default schemes cost one extra op, emitted first; the
    // default writes nothing so default-scheme files stay
    // byte-identical to pre-scheme ones.
    let scheme_ops = usize::from(!spec.scheme().is_default());
    let n_ops = spec.layers().len() + scheme_ops;
    for v in [ic, ih, iw, spec.classes(), n_ops] {
        w.write_all(&(v as u32).to_le_bytes())?;
    }
    if scheme_ops > 0 {
        w.write_all(&[OP_SCHEME])?;
        w.write_all(
            &u32::from(spec.scheme().wire_byte()).to_le_bytes(),
        )?;
    }
    for op in spec.layers() {
        match op {
            LayerSpec::Conv2d { cout, ksize, stride, pad, binarized } => {
                w.write_all(&[OP_CONV2D])?;
                for v in [*cout, *ksize, *stride, *pad] {
                    w.write_all(&(v as u32).to_le_bytes())?;
                }
                w.write_all(&[u8::from(*binarized)])?;
            }
            LayerSpec::MaxPool2 => w.write_all(&[OP_MAXPOOL2])?,
            LayerSpec::BatchNorm => w.write_all(&[OP_BATCHNORM])?,
            LayerSpec::Sign => w.write_all(&[OP_SIGN])?,
            LayerSpec::Flatten => w.write_all(&[OP_FLATTEN])?,
            LayerSpec::Linear { dout, binarized } => {
                w.write_all(&[OP_LINEAR])?;
                w.write_all(&(*dout as u32).to_le_bytes())?;
                w.write_all(&[u8::from(*binarized)])?;
            }
        }
    }
    Ok(())
}

impl WeightFile {
    /// Assemble a legacy (spec-less) weight file from in-memory tensors
    /// — callers rely on the `meta.widths` tensor for the architecture,
    /// exactly like a parsed BKW1 file.
    pub fn from_tensors(tensors: BTreeMap<String, WeightTensor>) -> Self {
        Self { tensors, spec: None, labels: None }
    }

    /// Assemble a weight file carrying its own architecture — the BKW2
    /// path used by `testing::synthetic_engine_spec` and the writer.
    pub fn from_tensors_with_spec(
        tensors: BTreeMap<String, WeightTensor>,
        spec: NetSpec,
    ) -> Self {
        Self { tensors, spec: Some(spec), labels: None }
    }

    /// Parse a BKW1 or BKW2 stream, section by section (tensor
    /// payloads decode chunkwise; no whole-file buffer is built).
    pub fn parse(r: impl Read) -> Result<Self, FormatError> {
        parse_from(&mut StreamSource::new(r))
    }

    /// Parse an already-mapped buffer; tensor payloads borrow `map`
    /// zero-copy (see [`WeightTensor::words`]).
    pub fn parse_mapped(map: Arc<Mmap>) -> Result<Self, FormatError> {
        parse_from(&mut MapSource::new(map))
    }

    /// Open a BKW file through a read-only memory mapping: tensor
    /// payloads reference the mapping instead of being copied onto the
    /// heap, so a cold model costs address space (plus the small
    /// header/spec/label structures), not resident memory, until its
    /// pages are touched.  The registry's mount path loads every model
    /// this way.
    pub fn open_mmap(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let map = Mmap::open(path)
            .with_context(|| format!("map {}", path.display()))?;
        Self::parse_mapped(Arc::new(map))
            .with_context(|| format!("parse {}", path.display()))
    }

    /// Whether any tensor borrows a file mapping (the
    /// [`WeightFile::open_mmap`] path).
    pub fn is_mapped(&self) -> bool {
        self.tensors.values().any(WeightTensor::is_mapped)
    }

    /// Serialize: BKW2 when the file carries a spec, BKW1 otherwise.
    /// A non-empty label table rides as the trailing labels section of
    /// either version (an empty table writes nothing — the label-less
    /// file, mirroring python's `labels=[]`).  Everything written here
    /// re-parses: a table whose entry count disagrees with the
    /// embedded spec's class count is refused with the same
    /// [`FormatError::LabelClassMismatch`] the reader would raise.
    pub fn write_to(&self, mut w: impl Write) -> Result<(), FormatError> {
        if let (Some(labels), Some(spec)) = (&self.labels, &self.spec) {
            if !labels.is_empty() && labels.len() != spec.classes() {
                return Err(FormatError::LabelClassMismatch {
                    labels: labels.len(),
                    classes: spec.classes(),
                });
            }
        }
        match &self.spec {
            Some(spec) => {
                w.write_all(b"BKW2")?;
                write_spec(&mut w, spec)?;
            }
            None => w.write_all(b"BKW1")?,
        }
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            let nb = name.as_bytes();
            w.write_all(&(nb.len() as u16).to_le_bytes())?;
            w.write_all(nb)?;
            w.write_all(&[match t.dtype {
                Dtype::F32 => 0u8,
                Dtype::U32 => 1u8,
            }])?;
            w.write_all(&[t.shape.len() as u8])?;
            for &d in &t.shape {
                w.write_all(&(d as u32).to_le_bytes())?;
            }
            for &word in t.words().iter() {
                w.write_all(&word.to_le_bytes())?;
            }
        }
        if let Some(labels) = self.labels.as_deref() {
            if !labels.is_empty() {
                write_labels(&mut w, labels)?;
            }
        }
        Ok(())
    }

    /// Serialize to a byte vector (see [`WeightFile::write_to`]).
    /// Panics on a label table the wire format cannot carry or that
    /// disagrees with the spec's class count (use
    /// [`WeightFile::write_to`] for the typed error).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.write_to(&mut out).expect(
            "in-memory serialization (only label-table validation can \
             fail here)",
        );
        out
    }

    /// Load a BKW file from disk (streaming — see
    /// [`WeightFile::open_mmap`] for the zero-copy path).
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        Self::parse(std::io::BufReader::new(f))
            .with_context(|| format!("parse {}", path.display()))
    }

    /// Write a BKW file to disk (BKW2 iff a spec is embedded).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        self.write_to(std::io::BufWriter::new(f))
            .with_context(|| format!("write {}", path.display()))
    }

    /// The embedded architecture, when the file is BKW2.
    pub fn embedded_spec(&self) -> Option<&NetSpec> {
        self.spec.as_ref()
    }

    /// The class-label table, when the file carries one (label-less
    /// files serve with numeric labels).
    pub fn labels(&self) -> Option<&[String]> {
        self.labels.as_deref()
    }

    /// Attach (or clear) the class-label table written as the trailing
    /// labels section; entry `i` names class `i`.  An empty table is
    /// equivalent to `None` at write time (no section is emitted); a
    /// non-empty table must have one entry per class or
    /// [`WeightFile::write_to`] refuses it.
    pub fn set_labels(&mut self, labels: Option<Vec<String>>) {
        self.labels = labels;
    }

    /// The architecture this file describes: the embedded BKW2 spec,
    /// or (BKW1) the legacy spec synthesized from `meta.widths`.
    pub fn net_spec(&self) -> Result<NetSpec> {
        match &self.spec {
            Some(spec) => Ok(spec.clone()),
            None => NetSpec::from_widths(&self.widths()?)
                .context("synthesizing legacy spec from meta.widths"),
        }
    }

    /// Wire version this file round-trips as (1 or 2).
    pub fn version(&self) -> u8 {
        if self.spec.is_some() { 2 } else { 1 }
    }

    /// Look one tensor up by name.
    pub fn get(&self, name: &str) -> Result<&WeightTensor, FormatError> {
        self.tensors
            .get(name)
            .ok_or_else(|| FormatError::MissingTensor(name.to_string()))
    }

    /// Every tensor name, sorted.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.keys().map(|s| s.as_str())
    }

    /// Number of tensors.
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    /// Whether the file holds no tensors.
    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// The legacy architecture widths vector (meta.widths).
    pub fn widths(&self) -> Result<Vec<u32>, FormatError> {
        Ok(self.get("meta.widths")?.as_u32()?.into_owned())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny BKW1 blob in memory.
    fn sample_blob() -> Vec<u8> {
        let mut out = Vec::new();
        out.extend(b"BKW1");
        out.extend(2u32.to_le_bytes());
        // tensor 1: meta.widths u32[3]
        let name = b"meta.widths";
        out.extend((name.len() as u16).to_le_bytes());
        out.extend(name);
        out.push(1); // u32
        out.push(1); // ndim
        out.extend(3u32.to_le_bytes());
        for v in [8u32, 16, 10] {
            out.extend(v.to_le_bytes());
        }
        // tensor 2: conv1.w f32[2,2]
        let name = b"conv1.w";
        out.extend((name.len() as u16).to_le_bytes());
        out.extend(name);
        out.push(0); // f32
        out.push(2); // ndim
        out.extend(2u32.to_le_bytes());
        out.extend(2u32.to_le_bytes());
        for v in [1.0f32, -1.0, 1.0, 1.0] {
            out.extend(v.to_bits().to_le_bytes());
        }
        out
    }

    /// Write `bytes` to a temp file and hand the path to `f`.
    fn with_temp_file<T>(tag: &str, bytes: &[u8],
                         f: impl FnOnce(&std::path::Path) -> T) -> T {
        let dir = std::env::temp_dir()
            .join(format!("bk-fmt-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("file.bkw");
        std::fs::write(&path, bytes).unwrap();
        let out = f(&path);
        std::fs::remove_dir_all(&dir).ok();
        out
    }

    #[test]
    fn parse_sample() {
        let wf = WeightFile::parse(&sample_blob()[..]).unwrap();
        assert_eq!(wf.len(), 2);
        assert_eq!(wf.version(), 1);
        assert!(wf.embedded_spec().is_none());
        assert!(!wf.is_mapped());
        assert_eq!(&*wf.get("meta.widths").unwrap().as_u32().unwrap(),
                   &[8, 16, 10]);
        let w = wf.get("conv1.w").unwrap();
        assert_eq!(w.shape, vec![2, 2]);
        assert_eq!(w.as_f32().unwrap(), vec![1.0, -1.0, 1.0, 1.0]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut blob = sample_blob();
        blob[0] = b'X';
        assert!(matches!(WeightFile::parse(&blob[..]),
                         Err(FormatError::BadMagic(_))));
    }

    #[test]
    fn rejects_truncated_with_section_and_counts() {
        let blob = sample_blob();
        // Cut inside the last tensor's payload: the error names the
        // section and how many bytes of the 16-byte field arrived.
        match WeightFile::parse(&blob[..blob.len() - 3]) {
            Err(FormatError::Truncated { section, needed, got }) => {
                assert_eq!(section, "tensor data");
                assert_eq!(needed, 16);
                assert_eq!(got, 13);
            }
            other => panic!("expected Truncated, got {other:?}"),
        }
        // Cut inside the magic itself.
        assert!(matches!(
            WeightFile::parse(&blob[..2]),
            Err(FormatError::Truncated { section: "magic", .. })
        ));
        // The mmap path reports the same typed error.
        with_temp_file("trunc", &blob[..blob.len() - 3], |path| {
            match WeightFile::open_mmap(path)
                .unwrap_err()
                .downcast::<FormatError>()
                .unwrap()
            {
                FormatError::Truncated { section, needed, got } => {
                    assert_eq!(section, "tensor data");
                    assert_eq!(needed, 16);
                    assert_eq!(got, 13);
                }
                other => panic!("expected Truncated, got {other:?}"),
            }
        });
    }

    #[test]
    fn open_mmap_round_trips_zero_copy() {
        let blob = sample_blob();
        with_temp_file("mmap", &blob, |path| {
            let mapped = WeightFile::open_mmap(path).unwrap();
            assert!(mapped.is_mapped());
            assert!(mapped.get("conv1.w").unwrap().is_mapped());
            let streamed = WeightFile::parse(&blob[..]).unwrap();
            // Identical content through both storage strategies.
            assert_eq!(mapped.len(), streamed.len());
            for name in streamed.names() {
                let (a, b) =
                    (mapped.get(name).unwrap(), streamed.get(name).unwrap());
                assert_eq!(a.shape, b.shape, "{name}");
                assert_eq!(a.words(), b.words(), "{name}");
            }
            assert_eq!(
                mapped.get("conv1.w").unwrap().as_f32().unwrap(),
                vec![1.0, -1.0, 1.0, 1.0]
            );
            assert_eq!(&*mapped.get("meta.widths").unwrap().as_u32().unwrap(),
                       &[8, 16, 10]);
            // And the writer re-serializes mapped tensors byte-exact.
            assert_eq!(mapped.to_bytes(), blob);
        });
    }

    #[test]
    fn dtype_mismatch_errors() {
        let wf = WeightFile::parse(&sample_blob()[..]).unwrap();
        assert!(wf.get("conv1.w").unwrap().as_u32().is_err());
        assert!(wf.get("meta.widths").unwrap().as_f32().is_err());
    }

    #[test]
    fn missing_tensor_errors() {
        let wf = WeightFile::parse(&sample_blob()[..]).unwrap();
        assert!(matches!(wf.get("nope"),
                         Err(FormatError::MissingTensor(_))));
    }

    #[test]
    fn bkw1_round_trips_through_writer() {
        let wf = WeightFile::parse(&sample_blob()[..]).unwrap();
        let bytes = wf.to_bytes();
        assert_eq!(&bytes[..4], b"BKW1");
        let back = WeightFile::parse(&bytes[..]).unwrap();
        assert_eq!(back.len(), wf.len());
        assert_eq!(back.get("conv1.w").unwrap().as_f32().unwrap(),
                   wf.get("conv1.w").unwrap().as_f32().unwrap());
    }

    #[test]
    fn bkw2_embeds_and_round_trips_the_spec() {
        let spec = NetSpec::builder((1, 4, 4))
            .conv(2, 3)
            .linear(3)
            .build()
            .unwrap();
        let wf = WeightFile::from_tensors_with_spec(
            BTreeMap::new(),
            spec.clone(),
        );
        assert_eq!(wf.version(), 2);
        let bytes = wf.to_bytes();
        assert_eq!(&bytes[..4], b"BKW2");
        let back = WeightFile::parse(&bytes[..]).unwrap();
        assert_eq!(back.embedded_spec(), Some(&spec));
        assert_eq!(back.net_spec().unwrap(), spec);
    }

    #[test]
    fn bkw2_scheme_round_trips_every_scheme() {
        for scheme in QuantScheme::ALL {
            let spec = NetSpec::builder((1, 4, 4))
                .conv(2, 3)
                .linear(3)
                .scheme(scheme)
                .build()
                .unwrap();
            let wf = WeightFile::from_tensors_with_spec(
                BTreeMap::new(),
                spec.clone(),
            );
            let back = WeightFile::parse(&wf.to_bytes()[..]).unwrap();
            assert_eq!(back.embedded_spec(), Some(&spec), "{scheme}");
            assert_eq!(
                back.net_spec().unwrap().scheme(),
                scheme,
                "{scheme}"
            );
        }
    }

    #[test]
    fn default_scheme_writes_no_scheme_op() {
        // The default scheme adds zero bytes, so pre-scheme readers
        // (and files) stay compatible: a non-default spec costs
        // exactly one scheme op (1 opcode + 4 payload bytes) more.
        let build = |scheme| {
            let spec = NetSpec::builder((1, 4, 4))
                .conv(2, 3)
                .linear(3)
                .scheme(scheme)
                .build()
                .unwrap();
            WeightFile::from_tensors_with_spec(BTreeMap::new(), spec)
                .to_bytes()
        };
        let default_bytes = build(QuantScheme::default());
        for scheme in QuantScheme::ALL {
            let bytes = build(scheme);
            if scheme.is_default() {
                assert_eq!(bytes, default_bytes);
            } else {
                assert_eq!(bytes.len(), default_bytes.len() + 5,
                           "{scheme}");
            }
        }
    }

    #[test]
    fn bad_and_duplicate_scheme_ops_are_rejected() {
        // BKW2, input 1x4x4, classes 3, ops [scheme, linear].
        let craft = |scheme_payloads: &[u32]| {
            let mut out = Vec::new();
            out.extend(b"BKW2");
            let n_ops = scheme_payloads.len() + 1;
            for v in [1u32, 4, 4, 3, n_ops as u32] {
                out.extend(v.to_le_bytes());
            }
            for &p in scheme_payloads {
                out.push(6); // scheme opcode
                out.extend(p.to_le_bytes());
            }
            out.push(5); // linear opcode
            out.extend(3u32.to_le_bytes());
            out.push(0); // not binarized
            out.extend(0u32.to_le_bytes()); // zero tensors
            out
        };
        // A known scheme parses ...
        let wf = WeightFile::parse(&craft(&[1])[..]).unwrap();
        assert_eq!(
            wf.net_spec().unwrap().scheme(),
            QuantScheme::from_wire_byte(1).unwrap()
        );
        // ... an unknown value is the typed error ...
        assert!(matches!(WeightFile::parse(&craft(&[99])[..]),
                         Err(FormatError::BadScheme(99))));
        // ... and a second scheme op is corruption.
        assert!(matches!(WeightFile::parse(&craft(&[1, 1])[..]),
                         Err(FormatError::DuplicateScheme)));
    }

    #[test]
    fn bkw2_with_invalid_spec_is_rejected() {
        // A structurally valid spec section describing an invalid net
        // (no final linear): input 1x2x2, classes 5, ops [flatten].
        let mut out = Vec::new();
        out.extend(b"BKW2");
        for v in [1u32, 2, 2, 5, 1] {
            out.extend(v.to_le_bytes());
        }
        out.push(4); // flatten opcode
        out.extend(0u32.to_le_bytes()); // zero tensors
        assert!(matches!(WeightFile::parse(&out[..]),
                         Err(FormatError::Spec(_))));
    }

    #[test]
    fn labels_round_trip_and_default_to_none() {
        let spec = NetSpec::builder((1, 4, 4))
            .conv(2, 3)
            .linear(3)
            .build()
            .unwrap();
        let mut wf = WeightFile::from_tensors_with_spec(
            BTreeMap::new(),
            spec.clone(),
        );
        assert!(wf.labels().is_none());
        wf.set_labels(Some(vec![
            "ant".into(), "bee".into(), "cat".into(),
        ]));
        let back = WeightFile::parse(&wf.to_bytes()[..]).unwrap();
        assert_eq!(back.labels(),
                   Some(&["ant".to_string(), "bee".into(),
                          "cat".into()][..]));
        assert_eq!(back.embedded_spec(), Some(&spec));
        // Label-less files still round-trip with no trailing section.
        wf.set_labels(None);
        let bytes = wf.to_bytes();
        assert!(!bytes.windows(4).any(|w| w == b"LBLS"));
        assert!(WeightFile::parse(&bytes[..])
            .unwrap()
            .labels()
            .is_none());
    }

    #[test]
    fn labels_on_bkw1_round_trip() {
        let mut wf = WeightFile::parse(&sample_blob()[..]).unwrap();
        wf.set_labels(Some(vec!["a".into(), "b".into()]));
        let back = WeightFile::parse(&wf.to_bytes()[..]).unwrap();
        assert_eq!(back.version(), 1);
        assert_eq!(back.labels().map(<[String]>::len), Some(2));
    }

    #[test]
    fn label_count_must_match_spec_classes() {
        let spec = NetSpec::builder((1, 4, 4))
            .linear(3)
            .build()
            .unwrap();
        let mut wf = WeightFile::from_tensors_with_spec(
            BTreeMap::new(),
            spec,
        );
        // The WRITER refuses a mismatched table (save never produces
        // a file the stack cannot load back)...
        wf.set_labels(Some(vec!["only-one".into()]));
        assert!(matches!(
            wf.write_to(&mut Vec::new()),
            Err(FormatError::LabelClassMismatch { labels: 1, classes: 3 })
        ));
        // ... an EMPTY table is the label-less file ...
        wf.set_labels(Some(Vec::new()));
        let bytes = wf.to_bytes();
        assert!(!bytes.windows(4).any(|w| w == b"LBLS"));
        assert!(WeightFile::parse(&bytes[..])
            .unwrap()
            .labels()
            .is_none());
        // ... and the READER still rejects a mismatched section from a
        // foreign writer (hand-crafted trailer on the same file).
        let mut crafted = bytes;
        crafted.extend(b"LBLS");
        crafted.extend(1u32.to_le_bytes());
        crafted.extend(3u16.to_le_bytes());
        crafted.extend(b"one");
        assert!(matches!(
            WeightFile::parse(&crafted[..]),
            Err(FormatError::LabelClassMismatch { labels: 1, classes: 3 })
        ));
    }

    #[test]
    fn bad_trailing_magic_is_rejected() {
        let mut blob = sample_blob();
        blob.extend(b"JUNK");
        assert!(matches!(WeightFile::parse(&blob[..]),
                         Err(FormatError::BadLabelMagic(_))));
        // A truncated trailer is the typed truncation error naming the
        // labels section, not a silent pass.
        let mut blob = sample_blob();
        blob.extend(b"LB");
        assert!(matches!(
            WeightFile::parse(&blob[..]),
            Err(FormatError::Truncated { section: "labels section", .. })
        ));
    }

    #[test]
    fn bytes_after_labels_section_are_rejected() {
        let mut wf = WeightFile::parse(&sample_blob()[..]).unwrap();
        wf.set_labels(Some(vec!["a".into(), "b".into()]));
        let mut blob = wf.to_bytes();
        assert!(WeightFile::parse(&blob[..]).is_ok());
        blob.push(0);
        assert!(matches!(WeightFile::parse(&blob[..]),
                         Err(FormatError::TrailingBytes)));
        // Same on the mmap path.
        with_temp_file("trail", &blob, |path| {
            assert!(matches!(
                WeightFile::open_mmap(path)
                    .unwrap_err()
                    .downcast::<FormatError>()
                    .unwrap(),
                FormatError::TrailingBytes
            ));
        });
    }

    #[test]
    fn oversized_labels_fail_to_write_instead_of_corrupting() {
        let mut wf = WeightFile::parse(&sample_blob()[..]).unwrap();
        wf.set_labels(Some(vec!["x".repeat(70_000), "b".into()]));
        let mut out = Vec::new();
        assert!(matches!(
            wf.write_to(&mut out),
            Err(FormatError::LabelTooLong { index: 0, len: 70_000 })
        ));
    }

    #[test]
    fn bkw2_bad_opcode_is_rejected() {
        let mut out = Vec::new();
        out.extend(b"BKW2");
        for v in [1u32, 2, 2, 5, 1] {
            out.extend(v.to_le_bytes());
        }
        out.push(99); // unknown opcode
        assert!(matches!(WeightFile::parse(&out[..]),
                         Err(FormatError::BadOpcode(99))));
    }
}
