//! BKW1 weight-file format (mirror of python/compile/train.py).
//!
//! ```text
//!     magic  b"BKW1"
//!     u32le  n_tensors
//!     n_tensors * {
//!         u16le name_len, name (utf-8),
//!         u8 dtype (0 = f32, 1 = u32),
//!         u8 ndim, ndim * u32le dims,
//!         data (little-endian, row-major)
//!     }
//! ```
//!
//! Contains `meta.widths` (u32[9]) plus, per layer, the sign-binarized
//! weight tensor and the folded BN affine (`bn_<layer>.a` / `.b`).

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 32-bit unsigned integer.
    U32,
}

/// One named tensor from a BKW1 file.
#[derive(Debug, Clone)]
pub struct WeightTensor {
    /// Element type.
    pub dtype: Dtype,
    /// Dimension sizes.
    pub shape: Vec<usize>,
    /// Raw little-endian words; reinterpret per `dtype`.
    pub words: Vec<u32>,
}

impl WeightTensor {
    /// Total element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// Whether the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The elements as f32 (errors on non-f32 tensors).
    pub fn as_f32(&self) -> Result<Vec<f32>> {
        ensure!(self.dtype == Dtype::F32, "tensor is not f32");
        Ok(self.words.iter().map(|&w| f32::from_bits(w)).collect())
    }

    /// The raw words of a u32 tensor (errors on non-u32 tensors).
    pub fn as_u32(&self) -> Result<&[u32]> {
        ensure!(self.dtype == Dtype::U32, "tensor is not u32");
        Ok(&self.words)
    }
}

/// A parsed BKW1 file.
#[derive(Debug, Clone)]
pub struct WeightFile {
    tensors: BTreeMap<String, WeightTensor>,
}

fn read_exact(r: &mut impl Read, n: usize) -> Result<Vec<u8>> {
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let b = read_exact(r, 2)?;
    Ok(u16::from_le_bytes([b[0], b[1]]))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let b = read_exact(r, 4)?;
    Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
}

impl WeightFile {
    /// Assemble a weight file from in-memory tensors — the synthetic-
    /// model path used by `testing::synthetic_engine` and tests that
    /// need a [`crate::model::BnnEngine`] without artifacts on disk.
    pub fn from_tensors(tensors: BTreeMap<String, WeightTensor>) -> Self {
        Self { tensors }
    }

    /// Parse a BKW1 stream.
    pub fn parse(mut r: impl Read) -> Result<Self> {
        let magic = read_exact(&mut r, 4)?;
        ensure!(&magic == b"BKW1", "bad magic {magic:?}");
        let n = read_u32(&mut r)? as usize;
        ensure!(n < 100_000, "implausible tensor count {n}");
        let mut tensors = BTreeMap::new();
        for _ in 0..n {
            let name_len = read_u16(&mut r)? as usize;
            let name = String::from_utf8(read_exact(&mut r, name_len)?)
                .context("tensor name not utf-8")?;
            let dt = read_exact(&mut r, 1)?[0];
            let dtype = match dt {
                0 => Dtype::F32,
                1 => Dtype::U32,
                _ => bail!("unknown dtype {dt} for '{name}'"),
            };
            let ndim = read_exact(&mut r, 1)?[0] as usize;
            ensure!(ndim <= 8, "implausible ndim {ndim}");
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut r)? as usize);
            }
            let count: usize = shape.iter().product();
            ensure!(count < 1 << 28, "implausible element count {count}");
            let raw = read_exact(&mut r, count * 4)
                .with_context(|| format!("data of '{name}'"))?;
            let words = raw
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(name, WeightTensor { dtype, shape, words });
        }
        Ok(Self { tensors })
    }

    /// Load a BKW1 file from disk.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        Self::parse(std::io::BufReader::new(f))
    }

    /// Look one tensor up by name.
    pub fn get(&self, name: &str) -> Result<&WeightTensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing tensor '{name}'"))
    }

    /// Every tensor name, sorted.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.keys().map(|s| s.as_str())
    }

    /// Number of tensors.
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    /// Whether the file holds no tensors.
    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// The architecture widths vector (meta.widths).
    pub fn widths(&self) -> Result<Vec<u32>> {
        Ok(self.get("meta.widths")?.as_u32()?.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny BKW1 blob in memory.
    fn sample_blob() -> Vec<u8> {
        let mut out = Vec::new();
        out.extend(b"BKW1");
        out.extend(2u32.to_le_bytes());
        // tensor 1: meta.widths u32[3]
        let name = b"meta.widths";
        out.extend((name.len() as u16).to_le_bytes());
        out.extend(name);
        out.push(1); // u32
        out.push(1); // ndim
        out.extend(3u32.to_le_bytes());
        for v in [8u32, 16, 10] {
            out.extend(v.to_le_bytes());
        }
        // tensor 2: conv1.w f32[2,2]
        let name = b"conv1.w";
        out.extend((name.len() as u16).to_le_bytes());
        out.extend(name);
        out.push(0); // f32
        out.push(2); // ndim
        out.extend(2u32.to_le_bytes());
        out.extend(2u32.to_le_bytes());
        for v in [1.0f32, -1.0, 1.0, 1.0] {
            out.extend(v.to_bits().to_le_bytes());
        }
        out
    }

    #[test]
    fn parse_sample() {
        let wf = WeightFile::parse(&sample_blob()[..]).unwrap();
        assert_eq!(wf.len(), 2);
        assert_eq!(wf.get("meta.widths").unwrap().as_u32().unwrap(),
                   &[8, 16, 10]);
        let w = wf.get("conv1.w").unwrap();
        assert_eq!(w.shape, vec![2, 2]);
        assert_eq!(w.as_f32().unwrap(), vec![1.0, -1.0, 1.0, 1.0]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut blob = sample_blob();
        blob[0] = b'X';
        assert!(WeightFile::parse(&blob[..]).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let blob = sample_blob();
        assert!(WeightFile::parse(&blob[..blob.len() - 3]).is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let wf = WeightFile::parse(&sample_blob()[..]).unwrap();
        assert!(wf.get("conv1.w").unwrap().as_u32().is_err());
        assert!(wf.get("meta.widths").unwrap().as_f32().is_err());
    }

    #[test]
    fn missing_tensor_errors() {
        let wf = WeightFile::parse(&sample_blob()[..]).unwrap();
        assert!(wf.get("nope").is_err());
    }
}
