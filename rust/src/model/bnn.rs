//! The native BNN inference engine — the Table-2 "CPU" arm.
//!
//! Executes ANY network a [`NetSpec`] validates (the paper's CIFAR net
//! is one point in that space) from a BKW1/BKW2 weight file, with the
//! gemm kernel swapped per [`EngineKernel`]:
//!
//! * `Xnor(imp)`  — "Our Kernel": encode + xnor-bitcount (Sec. 3)
//! * `Control`    — "Control Group": naive float-32 Gemm-Accumulation
//! * `Optimized`  — "PyTorch" row: blocked float gemm (the vendor-
//!   optimized stand-in)
//!
//! All three arms compute IDENTICAL logits (integer arithmetic on
//! {-1,+1}); `rust/tests/integration_engine.rs` pins that invariant, and
//! `integration_runtime.rs` pins agreement with the PJRT artifacts.
//!
//! Since the plan/session redesign the serving path is COMPILED, not
//! interpreted: [`BnnEngine::plan`] lowers the spec into a flat op
//! program once (all kernel dispatch resolved at plan time), and
//! [`super::plan::Session`] executes it against preallocated buffers —
//! see `model/plan.rs`.  The `forward*` methods here are thin
//! conveniences that compile a throwaway plan per call;
//! [`BnnEngine::forward_reference`] keeps the original unfused
//! layer-by-layer pipeline alive as the bit-exactness oracle for
//! `tests/plan_session.rs` and `tests/netspec.rs`.
//!
//! Non-binarized layers (conv1 of the paper's net, or any spec layer
//! with `binarized: false`) consume real-valued input in every arm
//! (see DESIGN.md §4): the Control arm runs them with the naive float
//! gemm, the other two with the SIMD float gemm.

use std::borrow::Cow;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::bitops::{pack_rows, XnorImpl};
use crate::gemm::GemmImpl;
use crate::nn::conv::{conv2d, ConvKernel, ConvParams, ConvScratch, ConvWeights};
use crate::nn::linear::{linear, LinearKernel};
use crate::nn::{argmax, bn_affine_nchw, bn_affine_rows, maxpool2};
use crate::tensor::{PackedMatrix, Tensor};

use super::format::WeightFile;
use super::spec::NetSpec;

/// Display name for `class` under an optional label table: the
/// table's entry when it has one, else the numeric class index as a
/// string.  The ONE fallback policy every surface shares (HTTP
/// replies, the classify/describe CLI, the examples) — change it
/// here, nowhere else.
pub fn label_for(labels: Option<&[String]>, class: usize) -> String {
    labels
        .and_then(|l| l.get(class))
        .cloned()
        .unwrap_or_else(|| class.to_string())
}

/// Which Table-2 arm to execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKernel {
    /// The paper's xnor-bitcount kernel, with the given implementation.
    Xnor(XnorImpl),
    /// The paper's control group: naive float gemm, no vendor library.
    Control,
    /// Vendor-optimized float stand-in (blocked gemm).
    Optimized,
}

impl EngineKernel {
    /// Arm label.  Borrowed (allocation-free) for every fixed variant;
    /// only `Xnor(Threaded(n))` allocates, because its thread count is
    /// dynamic.  The fixed `"xnor/<imp>"` strings are duplicated from
    /// [`XnorImpl::name`] precisely so they can stay borrowed; the
    /// `names` test below pins the two methods together.
    pub fn name(&self) -> Cow<'static, str> {
        match self {
            EngineKernel::Xnor(XnorImpl::Scalar) => "xnor/scalar32".into(),
            EngineKernel::Xnor(XnorImpl::Word64) => "xnor/word64".into(),
            EngineKernel::Xnor(XnorImpl::Blocked) => "xnor/blocked".into(),
            EngineKernel::Xnor(XnorImpl::Blocked2x4) => {
                "xnor/blocked2x4".into()
            }
            EngineKernel::Xnor(XnorImpl::Wide) => "xnor/wide64".into(),
            EngineKernel::Xnor(XnorImpl::Simd) => "xnor/simd".into(),
            EngineKernel::Xnor(XnorImpl::Auto) => "xnor/auto".into(),
            EngineKernel::Xnor(imp) => format!("xnor/{}", imp.name()).into(),
            EngineKernel::Control => "control".into(),
            EngineKernel::Optimized => "optimized".into(),
        }
    }

    /// Float gemm kernel used wherever a float conv/fc runs on this
    /// arm: the naive loop on Control (the paper's baseline), the
    /// widest SIMD kernel everywhere else (the vendor-optimized
    /// stand-in).  Shared by [`BnnEngine::plan`] and
    /// [`BnnEngine::forward_reference`] so the compiled path stays
    /// bit-identical to the oracle.
    pub(crate) fn float_impl(&self) -> GemmImpl {
        match self {
            EngineKernel::Control => GemmImpl::Naive,
            _ => GemmImpl::Simd,
        }
    }
}

/// One loaded conv layer.  Weight and BN buffers are `Arc`-shared with
/// every [`super::plan::Plan`] compiled from the engine, so plans are
/// self-contained (no lifetime back into the engine) without copying
/// matrices.
pub(crate) struct ConvLayer {
    pub(crate) params: ConvParams,
    pub(crate) pool: bool,
    pub(crate) binarized: bool,
    pub(crate) w_float: Arc<Vec<f32>>,
    /// Packed sign plane (sign-sign/α schemes), or the POSITIVE plane
    /// (`bit 1` iff `w > 0`) of a ternary layer.
    pub(crate) w_packed: Option<Arc<PackedMatrix>>,
    /// Ternary NEGATIVE plane (`bit 1` iff `w < 0`); `Some` exactly for
    /// binarized layers of a ternary-scheme net.
    pub(crate) w_packed_neg: Option<Arc<PackedMatrix>>,
    /// Per-output-channel α = E|w| (XNOR-Net schemes only).
    pub(crate) alpha: Option<Arc<Vec<f32>>>,
    pub(crate) bn_a: Arc<Vec<f32>>,
    pub(crate) bn_b: Arc<Vec<f32>>,
}

pub(crate) struct FcLayer {
    pub(crate) din: usize,
    pub(crate) dout: usize,
    pub(crate) binarized: bool,
    pub(crate) w_float: Arc<Vec<f32>>,
    /// See [`ConvLayer::w_packed`].
    pub(crate) w_packed: Option<Arc<PackedMatrix>>,
    /// See [`ConvLayer::w_packed_neg`].
    pub(crate) w_packed_neg: Option<Arc<PackedMatrix>>,
    /// See [`ConvLayer::alpha`].
    pub(crate) alpha: Option<Arc<Vec<f32>>>,
    pub(crate) bn_a: Arc<Vec<f32>>,
    pub(crate) bn_b: Arc<Vec<f32>>,
}

/// Pack one ternary bit-plane: `bit 1` where the predicate hits (+1),
/// `bit 0` (−1) elsewhere — so `(<pos,x> - <neg,x>) / 2` recovers the
/// exact ternary dot product (see [`crate::bitops::ternary_gemm`]).
fn pack_plane(w: &[f32], rows: usize, k: usize, positive: bool)
              -> PackedMatrix {
    let plane: Vec<f32> = w
        .iter()
        .map(|&v| {
            let hit = if positive { v > 0.0 } else { v < 0.0 };
            if hit { 1.0 } else { -1.0 }
        })
        .collect();
    pack_rows(&plane, rows, k)
}

/// How a binarized layer's weights are packed + scaled under `scheme`:
/// `(w_packed, w_packed_neg, wants_alpha)`.
fn pack_for_scheme(
    scheme: crate::model::spec::QuantScheme,
    w: &[f32],
    rows: usize,
    k: usize,
) -> (Option<Arc<PackedMatrix>>, Option<Arc<PackedMatrix>>) {
    if !scheme.signs_activations() {
        // Real-activation schemes run the float gemm arm unpacked.
        (None, None)
    } else if scheme.is_ternary() {
        (Some(Arc::new(pack_plane(w, rows, k, true))),
         Some(Arc::new(pack_plane(w, rows, k, false))))
    } else {
        (Some(Arc::new(pack_rows(w, rows, k))), None)
    }
}

/// In-place per-channel NCHW multiply `y = alpha[c] * x` (multiply
/// only — no `+ 0.0`, which would flip `-0.0` to `+0.0` and break
/// bit-identity with the fused α epilogues).
fn scale_channels_nchw(t: &mut Tensor, alpha: &[f32]) {
    let (b, c) = (t.dim(0), t.dim(1));
    let hw = t.dim(2) * t.dim(3);
    assert_eq!(alpha.len(), c, "alpha len");
    let data = t.data_mut();
    for bi in 0..b {
        for ci in 0..c {
            let sc = alpha[ci];
            for v in &mut data[(bi * c + ci) * hw..][..hw] {
                *v *= sc;
            }
        }
    }
}

/// In-place per-feature rows multiply `y = alpha[f] * x`.
fn scale_rows(t: &mut Tensor, alpha: &[f32]) {
    let d = t.dim(1);
    assert_eq!(alpha.len(), d, "alpha len");
    for row in t.data_mut().chunks_exact_mut(d) {
        for (v, &sc) in row.iter_mut().zip(alpha) {
            *v *= sc;
        }
    }
}

/// A loaded, ready-to-run BNN.
pub struct BnnEngine {
    /// The architecture IR: embedded in the weight file (BKW2) or
    /// synthesized from its legacy widths vector (BKW1).
    pub spec: NetSpec,
    /// Class-label table from the weight file's trailing labels
    /// section, when present (`labels[c]` names class `c`).  `Arc`d so
    /// compiled plans can carry it without copying.
    pub(crate) labels: Option<Arc<Vec<String>>>,
    pub(crate) convs: Vec<ConvLayer>,
    pub(crate) fcs: Vec<FcLayer>,
}

impl BnnEngine {
    /// Build from a parsed BKW file (binarized weights + folded BN).
    /// The weight tensors are looked up and shape-checked against the
    /// file's [`NetSpec`] under the canonical layer names
    /// ([`NetSpec::layer_names`]).
    pub fn from_weight_file(wf: &WeightFile) -> Result<Self> {
        let spec = wf.net_spec()?;
        let labels = match wf.labels() {
            Some(l) => {
                // BKW2 files were already checked at parse time; this
                // also covers BKW1 files (spec synthesized after the
                // labels were read) and in-memory assembly.
                ensure!(
                    l.len() == spec.classes(),
                    "label table has {} entries for {} classes",
                    l.len(),
                    spec.classes()
                );
                Some(Arc::new(l.to_vec()))
            }
            None => None,
        };
        let scheme = spec.scheme();
        let (cblocks, fblocks) = spec.blocks();
        let mut convs = Vec::with_capacity(cblocks.len());
        for s in &cblocks {
            let wt = wf.get(&format!("{}.w", s.name))?;
            ensure!(
                wt.shape == vec![s.cout, s.cin, s.ksize, s.ksize],
                "{}: shape {:?} (spec wants [{}, {}, {}, {}])",
                s.name, wt.shape, s.cout, s.cin, s.ksize, s.ksize
            );
            let w = wt.as_f32()?; // row-major [D, C, k, k] == [D, K]
            let (packed, packed_neg) = if s.binarized {
                pack_for_scheme(scheme, &w, s.cout, s.k())
            } else {
                (None, None)
            };
            let alpha = if s.binarized && scheme.has_alpha() {
                let a = wf.get(&format!("{}.alpha", s.name))?.as_f32()?;
                ensure!(a.len() == s.cout, "{}.alpha length", s.name);
                Some(Arc::new(a))
            } else {
                None
            };
            let bn_a = wf.get(&format!("bn_{}.a", s.name))?.as_f32()?;
            let bn_b = wf.get(&format!("bn_{}.b", s.name))?.as_f32()?;
            ensure!(bn_a.len() == s.cout && bn_b.len() == s.cout,
                    "bn_{} length", s.name);
            convs.push(ConvLayer {
                params: ConvParams {
                    cout: s.cout,
                    cin: s.cin,
                    ksize: s.ksize,
                    stride: s.stride,
                    pad: s.pad,
                },
                pool: s.pool,
                binarized: s.binarized,
                w_float: Arc::new(w),
                w_packed: packed,
                w_packed_neg: packed_neg,
                alpha,
                bn_a: Arc::new(bn_a),
                bn_b: Arc::new(bn_b),
            });
        }
        let mut fcs = Vec::with_capacity(fblocks.len());
        for s in &fblocks {
            let wt = wf.get(&format!("{}.w", s.name))?;
            ensure!(wt.shape == vec![s.dout, s.din],
                    "{}: shape {:?} (spec wants [{}, {}])",
                    s.name, wt.shape, s.dout, s.din);
            let w = wt.as_f32()?;
            let (packed, packed_neg) = if s.binarized {
                pack_for_scheme(scheme, &w, s.dout, s.din)
            } else {
                (None, None)
            };
            let alpha = if s.binarized && scheme.has_alpha() {
                let a = wf.get(&format!("{}.alpha", s.name))?.as_f32()?;
                ensure!(a.len() == s.dout, "{}.alpha length", s.name);
                Some(Arc::new(a))
            } else {
                None
            };
            let bn_a = wf.get(&format!("bn_{}.a", s.name))?.as_f32()?;
            let bn_b = wf.get(&format!("bn_{}.b", s.name))?.as_f32()?;
            ensure!(bn_a.len() == s.dout && bn_b.len() == s.dout,
                    "bn_{} length", s.name);
            fcs.push(FcLayer {
                din: s.din,
                dout: s.dout,
                binarized: s.binarized,
                w_float: Arc::new(w),
                w_packed: packed,
                w_packed_neg: packed_neg,
                alpha,
                bn_a: Arc::new(bn_a),
                bn_b: Arc::new(bn_b),
            });
        }
        Ok(Self { spec, labels, convs, fcs })
    }

    /// Convenience: load straight from a .bkw path.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let wf = WeightFile::load(&path).context("loading weight file")?;
        Self::from_weight_file(&wf)
    }

    /// Convenience: load from a .bkw path through a read-only file
    /// mapping ([`WeightFile::open_mmap`]) — the registry's mount path.
    /// Building the engine packs/copies what inference needs, so the
    /// mapping itself may drop afterwards.
    pub fn load_mmap(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let wf =
            WeightFile::open_mmap(&path).context("mapping weight file")?;
        Self::from_weight_file(&wf)
    }

    /// The class-label table from the weight file, when it carried one
    /// (`labels()[c]` names class `c`; label-less files serve with
    /// numeric labels).
    pub fn labels(&self) -> Option<&[String]> {
        self.labels.as_ref().map(|l| &l[..])
    }

    /// [`label_for`] over this engine's label table.
    pub fn label_for(&self, class: usize) -> String {
        label_for(self.labels(), class)
    }

    /// Full forward pass: normalized NCHW images -> logits
    /// [B, classes].
    ///
    /// Convenience wrapper: compiles a throwaway [`super::plan::Plan`]
    /// sized for this batch.  Repeated callers should hold a
    /// plan/session themselves
    /// (`engine.plan(kernel, max_batch)?.session()`), which is the
    /// zero-allocation path.
    pub fn forward(&self, x: &Tensor, kernel: EngineKernel) -> Tensor {
        let mut session = self
            .plan(kernel, x.dim(0))
            .expect("batch must be non-empty (b >= 1)")
            .session();
        session.run(x).clone()
    }

    /// Forward pass with a per-op wall-time breakdown (perf tooling; see
    /// `cargo bench --bench profile` and EXPERIMENTS.md §Perf).  Thin
    /// wrapper over [`super::plan::Session::run_profiled`]; stage names
    /// follow the compiled op program (`conv2:encode`,
    /// `fc1:bn_sign_pack`, ...).
    pub fn forward_profiled(
        &self,
        x: &Tensor,
        kernel: EngineKernel,
    ) -> (Tensor, Vec<(String, f64)>) {
        let mut session = self
            .plan(kernel, x.dim(0))
            .expect("batch must be non-empty (b >= 1)")
            .session();
        let (out, stages) = session.run_profiled(x);
        (out.clone(), stages)
    }

    /// Predicted class per image.
    pub fn predict(&self, x: &Tensor, kernel: EngineKernel) -> Vec<usize> {
        let b = x.dim(0);
        let mut session = self
            .plan(kernel, b)
            .expect("batch must be non-empty (b >= 1)")
            .session();
        let logits = session.run(x);
        (0..b).map(|i| argmax(logits.row(i))).collect()
    }

    /// Accuracy over a normalized NCHW image tensor + labels.
    ///
    /// Runs one [`super::plan::Session`] across all batches: every batch
    /// is fed as a borrowed view of `images` (no per-batch slice copy)
    /// and reuses the session's activation buffers.
    pub fn evaluate(
        &self,
        images: &Tensor,
        labels: &[u8],
        kernel: EngineKernel,
        batch: usize,
    ) -> f32 {
        let n = images.dim(0);
        assert_eq!(labels.len(), n);
        let batch = batch.max(1).min(n.max(1));
        let (ic, ih, iw) = self.spec.input();
        let chw = ic * ih * iw;
        let mut session = self
            .plan(kernel, batch)
            .expect("batch must be non-empty (b >= 1)")
            .session();
        let mut correct = 0usize;
        let mut done = 0usize;
        while done < n {
            let b = batch.min(n - done);
            let logits = session
                .run_images(&images.data()[done * chw..(done + b) * chw], b);
            for i in 0..b {
                if argmax(logits.row(i)) == labels[done + i] as usize {
                    correct += 1;
                }
            }
            done += b;
        }
        correct as f32 / n as f32
    }

    /// The ORIGINAL unfused layer-by-layer pipeline, generalized to
    /// walk the spec's weighted blocks, kept as the bit-exactness
    /// oracle for the compiled plan path (see `tests/plan_session.rs`
    /// and `tests/netspec.rs`).  Allocates per layer; never use it for
    /// serving.
    ///
    /// The `Sign` ops of the IR are not executed separately here: every
    /// binarized conv/fc kernel binarizes its own input internally
    /// (sign is idempotent on {-1,+1}), exactly as validation pairs
    /// them.
    ///
    /// Scheme-aware, per [`NetSpec::scheme`]: schemes whose
    /// activations stay real-valued run every layer on the float-real
    /// arm (their binarized weights are already ±1 in the file);
    /// ternary layers run sign-then-float-gemm on EVERY arm — the
    /// ternary weights × sign activations product is exact small
    /// integers in f32, so any gemm order matches the two-plane
    /// popcount path bit for bit; α layers multiply the
    /// per-output-channel scale in right after the gemm (before pool
    /// and bn), mirroring the fused epilogues.
    pub fn forward_reference(&self, x: &Tensor, kernel: EngineKernel)
                             -> Tensor {
        let (ic, ih, iw) = self.spec.input();
        assert_eq!(x.dim(1), ic, "input channels");
        assert_eq!(x.dim(2), ih, "input height");
        assert_eq!(x.dim(3), iw, "input width");
        let scheme = self.spec.scheme();
        let signs = scheme.signs_activations();
        let mut scratch = ConvScratch::default();
        let mut h = x.clone();
        for layer in &self.convs {
            let (ck, w): (ConvKernel, ConvWeights) = if !layer.binarized
                || !signs
            {
                // Real-valued input in every arm.
                (ConvKernel::FloatReal(kernel.float_impl()),
                 ConvWeights::Float(Arc::clone(&layer.w_float)))
            } else if scheme.is_ternary() {
                (ConvKernel::FloatBinarized(kernel.float_impl()),
                 ConvWeights::Float(Arc::clone(&layer.w_float)))
            } else {
                match kernel {
                    EngineKernel::Xnor(imp) => (
                        ConvKernel::Xnor(imp),
                        ConvWeights::Packed(Arc::clone(
                            layer.w_packed.as_ref().expect("packed weights"),
                        )),
                    ),
                    _ => (
                        ConvKernel::FloatBinarized(kernel.float_impl()),
                        ConvWeights::Float(Arc::clone(&layer.w_float)),
                    ),
                }
            };
            h = conv2d(&h, &w, &layer.params, ck, &mut scratch);
            if let Some(alpha) = &layer.alpha {
                scale_channels_nchw(&mut h, alpha);
            }
            if layer.pool {
                h = maxpool2(&h);
            }
            bn_affine_nchw(&mut h, &layer.bn_a, &layer.bn_b);
        }

        // Flatten NCHW -> [B, C*H*W] (row-major: already (c, h, w) order).
        let b = h.dim(0);
        let feat = h.len() / b;
        let mut h = h.reshaped(vec![b, feat]);

        for layer in &self.fcs {
            assert_eq!(h.dim(1), layer.din);
            let (lk, w): (LinearKernel, ConvWeights) = if !layer.binarized
                || !signs
            {
                (LinearKernel::FloatReal(kernel.float_impl()),
                 ConvWeights::Float(Arc::clone(&layer.w_float)))
            } else if scheme.is_ternary() {
                (LinearKernel::FloatBinarized(kernel.float_impl()),
                 ConvWeights::Float(Arc::clone(&layer.w_float)))
            } else {
                match kernel {
                    EngineKernel::Xnor(imp) => (
                        LinearKernel::Xnor(imp),
                        ConvWeights::Packed(Arc::clone(
                            layer.w_packed.as_ref().expect("packed weights"),
                        )),
                    ),
                    _ => (
                        LinearKernel::FloatBinarized(kernel.float_impl()),
                        ConvWeights::Float(Arc::clone(&layer.w_float)),
                    ),
                }
            };
            h = linear(&h, &w, layer.dout, lk);
            if let Some(alpha) = &layer.alpha {
                scale_rows(&mut h, alpha);
            }
            bn_affine_rows(&mut h, &layer.bn_a, &layer.bn_b);
        }
        assert_eq!(h.dim(1), self.spec.classes());
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The hardcoded borrowed labels in `EngineKernel::name` must track
    /// `XnorImpl::name` — this is the drift guard for the duplication.
    #[test]
    fn kernel_names_track_xnor_impl_names() {
        for imp in [
            XnorImpl::Scalar,
            XnorImpl::Word64,
            XnorImpl::Blocked,
            XnorImpl::Blocked2x4,
            XnorImpl::Wide,
            XnorImpl::Simd,
            XnorImpl::Auto,
            XnorImpl::Threaded(3),
        ] {
            assert_eq!(
                EngineKernel::Xnor(imp).name(),
                format!("xnor/{}", imp.name()),
                "{imp:?}"
            );
        }
        assert_eq!(EngineKernel::Control.name(), "control");
        assert_eq!(EngineKernel::Optimized.name(), "optimized");
    }

    #[test]
    fn label_for_falls_back_to_numeric() {
        let table = vec!["circle".to_string(), "square".into()];
        assert_eq!(label_for(Some(&table), 1), "square");
        // Out-of-range and label-less both fall back numerically.
        assert_eq!(label_for(Some(&table), 7), "7");
        assert_eq!(label_for(None, 3), "3");
    }
}
