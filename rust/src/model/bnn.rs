//! The native BNN inference engine — the Table-2 "CPU" arm.
//!
//! Executes the exact network of python/compile/model.py from a BKW1
//! weight file, with the gemm kernel swapped per [`EngineKernel`]:
//!
//! * `Xnor(imp)`  — "Our Kernel": encode + xnor-bitcount (Sec. 3)
//! * `Control`    — "Control Group": naive float-32 Gemm-Accumulation
//! * `Optimized`  — "PyTorch" row: blocked float gemm (the vendor-
//!   optimized stand-in)
//!
//! All three arms compute IDENTICAL logits (integer arithmetic on
//! {-1,+1}); `rust/tests/integration_engine.rs` pins that invariant, and
//! `integration_runtime.rs` pins agreement with the PJRT artifacts.
//!
//! conv1 consumes the real-valued image in every arm (see DESIGN.md §4):
//! the Control arm runs it with the naive float gemm, the other two with
//! the blocked float gemm.

use anyhow::{ensure, Context, Result};

use crate::bitops::{pack_rows, XnorImpl};
use crate::gemm::GemmImpl;
use crate::nn::conv::{conv2d, ConvKernel, ConvParams, ConvScratch, ConvWeights};
use crate::nn::linear::{linear, LinearKernel};
use crate::nn::{argmax, bn_affine_nchw, bn_affine_rows, maxpool2};
use crate::tensor::Tensor;

use super::config::{ModelConfig, IMAGE_C, IMAGE_HW, NUM_CLASSES};
use super::format::WeightFile;

/// Which Table-2 arm to execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKernel {
    /// The paper's xnor-bitcount kernel, with the given implementation.
    Xnor(XnorImpl),
    /// The paper's control group: naive float gemm, no vendor library.
    Control,
    /// Vendor-optimized float stand-in (blocked gemm).
    Optimized,
}

impl EngineKernel {
    pub fn name(&self) -> String {
        match self {
            EngineKernel::Xnor(imp) => format!("xnor/{}", imp.name()),
            EngineKernel::Control => "control".into(),
            EngineKernel::Optimized => "optimized".into(),
        }
    }
}

struct ConvLayer {
    params: ConvParams,
    pool: bool,
    binarized: bool,
    w_float: ConvWeights,
    w_packed: Option<ConvWeights>,
    bn_a: Vec<f32>,
    bn_b: Vec<f32>,
}

struct FcLayer {
    din: usize,
    dout: usize,
    w_float: ConvWeights,
    w_packed: ConvWeights,
    bn_a: Vec<f32>,
    bn_b: Vec<f32>,
}

/// A loaded, ready-to-run BNN.
pub struct BnnEngine {
    pub cfg: ModelConfig,
    convs: Vec<ConvLayer>,
    fcs: Vec<FcLayer>,
}

impl BnnEngine {
    /// Build from a parsed BKW1 file (binarized weights + folded BN).
    pub fn from_weight_file(wf: &WeightFile) -> Result<Self> {
        let cfg = ModelConfig::from_widths(&wf.widths()?)?;
        let mut convs = Vec::with_capacity(cfg.convs.len());
        for s in &cfg.convs {
            let wt = wf.get(&format!("{}.w", s.name))?;
            ensure!(
                wt.shape == vec![s.cout, s.cin, s.ksize, s.ksize],
                "{}: shape {:?}", s.name, wt.shape
            );
            let w = wt.as_f32()?; // row-major [D, C, k, k] == [D, K]
            let packed = s
                .binarized
                .then(|| ConvWeights::Packed(pack_rows(&w, s.cout, s.k())));
            let bn_a = wf.get(&format!("bn_{}.a", s.name))?.as_f32()?;
            let bn_b = wf.get(&format!("bn_{}.b", s.name))?.as_f32()?;
            ensure!(bn_a.len() == s.cout && bn_b.len() == s.cout,
                    "bn_{} length", s.name);
            convs.push(ConvLayer {
                params: ConvParams {
                    cout: s.cout,
                    cin: s.cin,
                    ksize: s.ksize,
                    stride: s.stride,
                    pad: s.pad,
                },
                pool: s.pool,
                binarized: s.binarized,
                w_float: ConvWeights::Float(w),
                w_packed: packed,
                bn_a,
                bn_b,
            });
        }
        let mut fcs = Vec::with_capacity(cfg.fcs.len());
        for s in &cfg.fcs {
            let wt = wf.get(&format!("{}.w", s.name))?;
            ensure!(wt.shape == vec![s.dout, s.din],
                    "{}: shape {:?}", s.name, wt.shape);
            let w = wt.as_f32()?;
            let packed = ConvWeights::Packed(pack_rows(&w, s.dout, s.din));
            let bn_a = wf.get(&format!("bn_{}.a", s.name))?.as_f32()?;
            let bn_b = wf.get(&format!("bn_{}.b", s.name))?.as_f32()?;
            fcs.push(FcLayer {
                din: s.din,
                dout: s.dout,
                w_float: ConvWeights::Float(w),
                w_packed: packed,
                bn_a,
                bn_b,
            });
        }
        Ok(Self { cfg, convs, fcs })
    }

    /// Convenience: load straight from a .bkw path.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let wf = WeightFile::load(&path).context("loading weight file")?;
        Self::from_weight_file(&wf)
    }

    /// Full forward pass: normalized NCHW images -> logits [B, 10].
    pub fn forward(&self, x: &Tensor, kernel: EngineKernel) -> Tensor {
        let mut scratch = ConvScratch::default();
        self.forward_with_scratch(x, kernel, &mut scratch)
    }

    /// Forward pass with a per-layer wall-time breakdown (perf tooling;
    /// see `cargo bench --bench profile` and EXPERIMENTS.md §Perf).
    pub fn forward_profiled(
        &self,
        x: &Tensor,
        kernel: EngineKernel,
    ) -> (Tensor, Vec<(String, f64)>) {
        let mut scratch = ConvScratch::default();
        let mut stages = Vec::new();
        let out = self.forward_inner(x, kernel, &mut scratch,
                                     &mut Some(&mut stages));
        (out, stages)
    }

    /// Forward pass reusing caller-owned scratch (the serving hot path).
    pub fn forward_with_scratch(
        &self,
        x: &Tensor,
        kernel: EngineKernel,
        scratch: &mut ConvScratch,
    ) -> Tensor {
        self.forward_inner(x, kernel, scratch, &mut None)
    }

    fn forward_inner(
        &self,
        x: &Tensor,
        kernel: EngineKernel,
        scratch: &mut ConvScratch,
        stages: &mut Option<&mut Vec<(String, f64)>>,
    ) -> Tensor {
        use crate::utils::Stopwatch;
        macro_rules! stage {
            ($name:expr, $body:expr) => {{
                let sw = Stopwatch::start();
                let out = $body;
                if let Some(s) = stages.as_deref_mut() {
                    s.push(($name, sw.elapsed_secs()));
                }
                out
            }};
        }
        assert_eq!(x.dim(1), IMAGE_C);
        assert_eq!(x.dim(2), IMAGE_HW);
        let mut h = x.clone();
        for (li, layer) in self.convs.iter().enumerate() {
            let (ck, w): (ConvKernel, &ConvWeights) = if !layer.binarized {
                // conv1: float input in every arm.
                let imp = match kernel {
                    EngineKernel::Control => GemmImpl::Naive,
                    _ => GemmImpl::Blocked,
                };
                (ConvKernel::FloatReal(imp), &layer.w_float)
            } else {
                match kernel {
                    EngineKernel::Xnor(imp) => (
                        ConvKernel::Xnor(imp),
                        layer.w_packed.as_ref().expect("packed weights"),
                    ),
                    EngineKernel::Control => (
                        ConvKernel::FloatBinarized(GemmImpl::Naive),
                        &layer.w_float,
                    ),
                    EngineKernel::Optimized => (
                        ConvKernel::FloatBinarized(GemmImpl::Blocked),
                        &layer.w_float,
                    ),
                }
            };
            h = stage!(format!("conv{}", li + 1),
                       conv2d(&h, w, &layer.params, ck, scratch));
            if layer.pool {
                h = stage!(format!("pool{}", li + 1), maxpool2(&h));
            }
            bn_affine_nchw(&mut h, &layer.bn_a, &layer.bn_b);
        }

        // Flatten NCHW -> [B, C*H*W] (row-major: already (c, h, w) order).
        let b = h.dim(0);
        let feat = h.len() / b;
        let mut h = h.reshaped(vec![b, feat]);

        for (li, layer) in self.fcs.iter().enumerate() {
            assert_eq!(h.dim(1), layer.din);
            let (lk, w): (LinearKernel, &ConvWeights) = match kernel {
                EngineKernel::Xnor(imp) => {
                    (LinearKernel::Xnor(imp), &layer.w_packed)
                }
                EngineKernel::Control => (
                    LinearKernel::FloatBinarized(GemmImpl::Naive),
                    &layer.w_float,
                ),
                EngineKernel::Optimized => (
                    LinearKernel::FloatBinarized(GemmImpl::Blocked),
                    &layer.w_float,
                ),
            };
            h = stage!(format!("fc{}", li + 1),
                       linear(&h, w, layer.dout, lk));
            bn_affine_rows(&mut h, &layer.bn_a, &layer.bn_b);
        }
        assert_eq!(h.dim(1), NUM_CLASSES);
        h
    }

    /// Predicted class per image.
    pub fn predict(&self, x: &Tensor, kernel: EngineKernel) -> Vec<usize> {
        let logits = self.forward(x, kernel);
        let b = logits.dim(0);
        (0..b).map(|i| argmax(logits.row(i))).collect()
    }

    /// Accuracy over a normalized NCHW image tensor + labels.
    pub fn evaluate(
        &self,
        images: &Tensor,
        labels: &[u8],
        kernel: EngineKernel,
        batch: usize,
    ) -> f32 {
        let n = images.dim(0);
        assert_eq!(labels.len(), n);
        let chw = IMAGE_C * IMAGE_HW * IMAGE_HW;
        let mut correct = 0usize;
        let mut done = 0usize;
        let mut scratch = ConvScratch::default();
        while done < n {
            let b = batch.min(n - done);
            let slice = Tensor::new(
                vec![b, IMAGE_C, IMAGE_HW, IMAGE_HW],
                images.data()[done * chw..(done + b) * chw].to_vec(),
            );
            let logits = self.forward_with_scratch(
                &slice,
                kernel,
                &mut scratch,
            );
            for i in 0..b {
                if argmax(logits.row(i)) == labels[done + i] as usize {
                    correct += 1;
                }
            }
            done += b;
        }
        correct as f32 / n as f32
    }
}
