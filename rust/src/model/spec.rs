//! NetSpec — the typed architecture IR the whole engine plans from.
//!
//! The paper's kernel (xnor + bitcount) is architecture-agnostic; this
//! module makes the *engine* agnostic too.  A [`NetSpec`] describes any
//! binarized feed-forward network as an input shape plus an ordered
//! list of [`LayerSpec`] ops (`Conv2d`, `MaxPool2`, `BatchNorm`,
//! `Sign`, `Flatten`, `Linear`); construction validates the full shape
//! arithmetic and op grammar up front and returns typed [`SpecError`]s,
//! so everything downstream (weight loading, plan lowering,
//! `forward_reference`, session buffer sizing) can walk the IR without
//! re-checking it.
//!
//! # Op grammar
//!
//! The IR is a linear pipeline over one activation.  Validation
//! enforces the block structure every lowering relies on:
//!
//! ```text
//!     net      := conv_block*  Flatten  fc_block+
//!     conv_block := [Sign] Conv2d [MaxPool2] BatchNorm
//!     fc_block   := [Sign] Linear BatchNorm
//! ```
//!
//! * `Sign` binarizes the activation; it appears exactly before every
//!   `binarized` `Conv2d`/`Linear` (the flag and the op are
//!   cross-checked — a binarized layer without a preceding `Sign`, or a
//!   `Sign` feeding a non-binarized layer, is a [`SpecError`]).
//! * Every weighted layer carries exactly one folded `BatchNorm`
//!   affine (the weight format stores `bn_<layer>.a/.b` per layer);
//!   for convs the 2x2 `MaxPool2` sits between the conv and its
//!   BatchNorm, as in the reference pipeline.
//! * `MaxPool2` requires even spatial dims; `Conv2d` output dims must
//!   stay >= 1; `Linear` requires a `Flatten` first.
//! * The net ends with the BatchNorm of its final `Linear`, whose
//!   width is the class count.
//!
//! The canonical CIFAR net of the paper is one point in this space —
//! [`NetSpec::from_widths`] synthesizes it from a legacy BKW1
//! `meta.widths` vector, and BKW2 weight files embed their spec
//! directly (see `model::format`).
//!
//! # Building specs
//!
//! [`NetSpec::builder`] is the ergonomic path — it inserts the
//! `Sign`/`BatchNorm`/`Flatten` plumbing for you and binarizes every
//! weighted layer after the first (the XNOR-Net convention: the input
//! image stays real-valued):
//!
//! ```
//! use bitkernel::model::NetSpec;
//!
//! let spec = NetSpec::builder((1, 28, 28))
//!     .conv(16, 3)
//!     .pool()
//!     .conv(32, 3)
//!     .pool()
//!     .linear(64)
//!     .linear(26)
//!     .build()?;
//! assert_eq!(spec.classes(), 26);
//! # Ok::<(), bitkernel::model::SpecError>(())
//! ```

use crate::nn::im2col::out_hw;

/// Net-level quantization scheme: which domain the weighted layers'
/// operands live in and which epilogue the fused plan runs.  One scheme
/// governs the whole net (per the related-work model families); the
/// per-layer `binarized` flags still pick WHICH layers quantize.
///
/// Every `match` on this enum lives in this module, `model/plan.rs`,
/// `model/bnn.rs`, or `nn/fuse.rs` (enforced by a ci.sh grep gate);
/// everything else goes through the helper predicates below.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QuantScheme {
    /// sign(w)·sign(a) — the source paper's scheme and the BKW1/legacy
    /// default: both operands packed, pure xnor+popcount gemm.
    #[default]
    SignSign,
    /// XNOR-Net (Rastegari et al.): sign·sign gemm plus a
    /// per-output-channel f32 scale α = E|w| multiplied into the
    /// epilogue after the popcount.
    XnorAlpha,
    /// Binary-weight network (Courbariaux et al. line): sign-binarized
    /// weights, real-valued activations — runs on the float gemm arm.
    BinaryWeight,
    /// Ternary weights {-1, 0, +1} packed as two bit-planes,
    /// popcounted over both and combined; activations stay signs.
    TernaryWeight,
}

impl QuantScheme {
    /// Every scheme, for conformance-matrix enumeration.
    pub const ALL: [QuantScheme; 4] = [
        QuantScheme::SignSign,
        QuantScheme::XnorAlpha,
        QuantScheme::BinaryWeight,
        QuantScheme::TernaryWeight,
    ];

    /// Canonical lowercase name (BKW2 metadata, `describe`, `/models`).
    pub fn name(&self) -> &'static str {
        match self {
            QuantScheme::SignSign => "sign_sign",
            QuantScheme::XnorAlpha => "xnor_alpha",
            QuantScheme::BinaryWeight => "binary_weight",
            QuantScheme::TernaryWeight => "ternary_weight",
        }
    }

    /// Stable BKW2 wire value (pinned by conformance tests).
    pub fn wire_byte(&self) -> u8 {
        match self {
            QuantScheme::SignSign => 0,
            QuantScheme::XnorAlpha => 1,
            QuantScheme::BinaryWeight => 2,
            QuantScheme::TernaryWeight => 3,
        }
    }

    /// Inverse of [`QuantScheme::wire_byte`] (`None` for unknown
    /// values — the reader surfaces those as a typed format error).
    pub fn from_wire_byte(v: u8) -> Option<Self> {
        Self::ALL.into_iter().find(|s| s.wire_byte() == v)
    }

    /// Whether binarized layers consume sign-binarized ACTIVATIONS
    /// (false only for [`QuantScheme::BinaryWeight`], whose activations
    /// stay real-valued — its grammar carries no `Sign` ops).
    pub fn signs_activations(&self) -> bool {
        !matches!(self, QuantScheme::BinaryWeight)
    }

    /// Whether binarized layers carry a per-output-channel α tensor
    /// (`<layer>.alpha` in the weight file).
    pub fn has_alpha(&self) -> bool {
        matches!(self, QuantScheme::XnorAlpha)
    }

    /// Whether binarized weights are ternary (two packed bit-planes).
    pub fn is_ternary(&self) -> bool {
        matches!(self, QuantScheme::TernaryWeight)
    }

    /// Whether this is the legacy default ([`QuantScheme::SignSign`]);
    /// BKW2 files omit the scheme section for the default, so legacy
    /// bytes stay valid and new writers stay byte-identical on it.
    pub fn is_default(&self) -> bool {
        matches!(self, QuantScheme::SignSign)
    }
}

impl std::fmt::Display for QuantScheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One op of the architecture IR.  See the module docs for the grammar
/// validation enforces between ops.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LayerSpec {
    /// Square convolution (im2col + gemm).  `binarized` means the layer
    /// consumes its input as {-1,+1} signs and is xnor-eligible; its
    /// input channel count is derived from the incoming shape.
    Conv2d {
        /// Output channels.
        cout: usize,
        /// Square kernel side.
        ksize: usize,
        /// Stride (both dims).
        stride: usize,
        /// Zero padding (both dims).
        pad: usize,
        /// Consumes sign-binarized input (must be preceded by `Sign`).
        binarized: bool,
    },
    /// 2x2 max-pool, stride 2 (requires even spatial dims).
    MaxPool2,
    /// Folded inference-time BatchNorm: per-channel (image domain) or
    /// per-feature (rows domain) affine `y = a*x + b`, attributed to
    /// the preceding weighted layer.
    BatchNorm,
    /// Activation binarization `sign(x)` (+1 iff `x >= 0`); must feed a
    /// binarized `Conv2d`/`Linear`.
    Sign,
    /// NCHW -> rows reinterpretation (row-major: no data motion).
    Flatten,
    /// Fully-connected layer; input width is derived from the incoming
    /// shape.
    Linear {
        /// Output width.
        dout: usize,
        /// Consumes sign-binarized input (must be preceded by `Sign`).
        binarized: bool,
    },
}

impl LayerSpec {
    /// Short lowercase op name for errors and `describe` output.
    pub fn op_name(&self) -> &'static str {
        match self {
            LayerSpec::Conv2d { .. } => "conv2d",
            LayerSpec::MaxPool2 => "maxpool2",
            LayerSpec::BatchNorm => "batchnorm",
            LayerSpec::Sign => "sign",
            LayerSpec::Flatten => "flatten",
            LayerSpec::Linear { .. } => "linear",
        }
    }
}

/// Shape of the activation after an op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    /// Image-domain NCHW activation (per-image dims).
    Image {
        /// Channels.
        c: usize,
        /// Height.
        h: usize,
        /// Width.
        w: usize,
    },
    /// Flattened rows `[B, f]`.
    Rows {
        /// Feature width.
        f: usize,
    },
}

impl Shape {
    /// Elements per image/row.
    pub fn elems(&self) -> usize {
        match *self {
            Shape::Image { c, h, w } => c * h * w,
            Shape::Rows { f } => f,
        }
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Shape::Image { c, h, w } => write!(f, "{c}x{h}x{w}"),
            Shape::Rows { f: width } => write!(f, "[{width}]"),
        }
    }
}

/// Typed validation failures from [`NetSpec`] construction.  Every
/// variant names the offending op index so CLI errors point at the
/// exact spot in the layer list.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum SpecError {
    /// Input shape with a zero dim.
    #[error("input shape {0}x{1}x{2} has a zero dim")]
    ZeroInput(usize, usize, usize),
    /// A layer list with no ops at all.
    #[error("network has no layers")]
    Empty,
    /// An op that needs an image-domain activation got rows (or ran
    /// after `Flatten`).
    #[error("op {index} ({op}): expects an image activation, found {found}")]
    ExpectsImage {
        /// Offending op index.
        index: usize,
        /// Offending op name.
        op: &'static str,
        /// The activation shape actually seen.
        found: Shape,
    },
    /// `Linear` before any `Flatten`.
    #[error("op {index} (linear): expects flattened rows — add a flatten op first")]
    ExpectsRows {
        /// Offending op index.
        index: usize,
    },
    /// A conv with a zero dim or kernel/stride of zero.
    #[error("op {index} (conv2d): cout {cout}, ksize {ksize}, stride {stride} must all be >= 1")]
    BadConv {
        /// Offending op index.
        index: usize,
        /// Declared output channels.
        cout: usize,
        /// Declared kernel side.
        ksize: usize,
        /// Declared stride.
        stride: usize,
    },
    /// Conv geometry that yields an empty output plane.
    #[error("op {index} (conv2d): kernel {ksize} stride {stride} pad {pad} yields an empty output for a {found} input")]
    EmptyConvOutput {
        /// Offending op index.
        index: usize,
        /// Declared kernel side.
        ksize: usize,
        /// Declared stride.
        stride: usize,
        /// Declared padding.
        pad: usize,
        /// Input shape at that op.
        found: Shape,
    },
    /// `MaxPool2` over odd spatial dims.
    #[error("op {index} (maxpool2): spatial dims {h}x{w} are not even")]
    OddPool {
        /// Offending op index.
        index: usize,
        /// Input height.
        h: usize,
        /// Input width.
        w: usize,
    },
    /// `MaxPool2` not directly between a `Conv2d` and its `BatchNorm`.
    #[error("op {index} (maxpool2): must sit between a conv2d and its batchnorm")]
    DanglingPool {
        /// Offending op index.
        index: usize,
    },
    /// `BatchNorm` with no preceding weighted layer to attach to.
    #[error("op {index} (batchnorm): no preceding conv2d/linear to attach to")]
    DanglingBatchNorm {
        /// Offending op index.
        index: usize,
    },
    /// A weighted layer (or `Sign`/`Flatten`/end-of-net) arrived while
    /// the previous weighted layer still lacks its `BatchNorm`.
    #[error("op {index}: '{layer}' still needs its batchnorm first")]
    MissingBatchNorm {
        /// Index of the op that arrived too early (or `layers.len()`
        /// when the net simply ends without the BatchNorm).
        index: usize,
        /// Name of the weighted layer that lacks a BatchNorm.
        layer: String,
    },
    /// A `Sign` op not consumed by a directly following binarized
    /// weighted layer.
    #[error("op {index} (sign): must directly feed a binarized conv2d/linear")]
    DanglingSign {
        /// Offending op index.
        index: usize,
    },
    /// A binarized weighted layer without its `Sign`.
    #[error("op {index} ({op}): binarized layers must be directly preceded by a sign op")]
    UnsignedBinarized {
        /// Offending op index.
        index: usize,
        /// Offending op name.
        op: &'static str,
    },
    /// A `Linear` with zero width.
    #[error("op {index} (linear): dout must be >= 1")]
    BadLinear {
        /// Offending op index.
        index: usize,
    },
    /// The net does not end with a batchnorm'd `Linear`.
    #[error("network must end with a linear layer (followed by its batchnorm)")]
    NoFinalLinear,
    /// Declared class count disagrees with the final linear width.
    #[error("final linear width {dout} != declared class count {classes}")]
    ClassMismatch {
        /// Final linear width.
        dout: usize,
        /// Declared class count.
        classes: usize,
    },
    /// A legacy BKW1 widths vector of the wrong shape.
    #[error("legacy widths vector must be [c1..c6, f1, f2, classes] with c5 == c6; got {0}")]
    LegacyWidths(String),
    /// `plan` asked for a zero-sized batch.
    #[error("max_batch must be >= 1")]
    ZeroBatch,
    /// Builder misuse, surfaced at `build()` (e.g. `.pool()` with no
    /// preceding conv).
    #[error("builder: {0}")]
    Builder(String),
}

/// A validated architecture: input shape, class count, and the op list,
/// plus the per-op output shapes computed during validation.
///
/// Construction ([`NetSpec::new`], [`NetSpec::builder`],
/// [`NetSpec::from_widths`], or a BKW2 file read) is the ONLY way to
/// obtain one, so holding a `NetSpec` is proof the architecture is
/// well-formed — plan lowering and weight loading walk it without
/// re-validating.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetSpec {
    input: (usize, usize, usize),
    classes: usize,
    scheme: QuantScheme,
    layers: Vec<LayerSpec>,
    /// Shape AFTER each op (parallel to `layers`).
    shapes: Vec<Shape>,
}

/// Internal per-weighted-layer view derived from the validated op list
/// — the shape the engine loader and plan lowering actually walk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) struct ConvBlock {
    /// Canonical weight-file key prefix (`conv1`, `conv2`, ...).
    pub(crate) name: String,
    pub(crate) cin: usize,
    pub(crate) cout: usize,
    pub(crate) ksize: usize,
    pub(crate) stride: usize,
    pub(crate) pad: usize,
    /// 2x2 max-pool between this conv and its batchnorm.
    pub(crate) pool: bool,
    pub(crate) binarized: bool,
}

impl ConvBlock {
    /// Gemm reduction length K = Cin * k * k.
    pub(crate) fn k(&self) -> usize {
        self.cin * self.ksize * self.ksize
    }
}

/// Internal fully-connected view (see [`ConvBlock`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) struct FcBlock {
    /// Canonical weight-file key prefix (`fc1`, `fc2`, ...).
    pub(crate) name: String,
    pub(crate) din: usize,
    pub(crate) dout: usize,
    pub(crate) binarized: bool,
}

impl NetSpec {
    /// Validate `layers` against `input` (C, H, W) and build the spec
    /// with the legacy default scheme ([`QuantScheme::SignSign`]).
    /// The class count is the final linear width.
    pub fn new(
        input: (usize, usize, usize),
        layers: Vec<LayerSpec>,
    ) -> Result<Self, SpecError> {
        Self::new_with_scheme(input, layers, QuantScheme::SignSign)
    }

    /// [`NetSpec::new`] under an explicit [`QuantScheme`].  Validation
    /// is scheme-aware: schemes whose activations stay real-valued
    /// (see [`QuantScheme::signs_activations`]) forbid `Sign` ops —
    /// there is nothing for them to feed — while the binarized flags
    /// still mark which layers quantize their weights.
    pub fn new_with_scheme(
        input: (usize, usize, usize),
        layers: Vec<LayerSpec>,
        scheme: QuantScheme,
    ) -> Result<Self, SpecError> {
        let (ic, ih, iw) = input;
        if ic == 0 || ih == 0 || iw == 0 {
            return Err(SpecError::ZeroInput(ic, ih, iw));
        }
        if layers.is_empty() {
            return Err(SpecError::Empty);
        }

        // Walked state: current shape, whether a Sign is waiting to be
        // consumed, and which weighted layer still owes a BatchNorm.
        // Under schemes with real activations a `Sign` can never be
        // consumed, so the (binarized-and-signed, pending_sign)
        // cross-checks below flag it as dangling.
        let signs = scheme.signs_activations();
        let mut shape = Shape::Image { c: ic, h: ih, w: iw };
        let mut shapes = Vec::with_capacity(layers.len());
        let mut pending_sign = false;
        // (display name, is_conv, pooled) of the bn-less weighted layer.
        let mut awaiting_bn: Option<(String, bool, bool)> = None;
        let mut last_linear_dout: Option<usize> = None;
        let (mut nconv, mut nfc) = (0usize, 0usize);

        for (index, op) in layers.iter().enumerate() {
            match op {
                LayerSpec::Conv2d { cout, ksize, stride, pad, binarized } => {
                    if let Some((layer, _, _)) = awaiting_bn.take() {
                        return Err(SpecError::MissingBatchNorm {
                            index,
                            layer,
                        });
                    }
                    let Shape::Image { c: _, h, w } = shape else {
                        return Err(SpecError::ExpectsImage {
                            index,
                            op: op.op_name(),
                            found: shape,
                        });
                    };
                    match (*binarized && signs, pending_sign) {
                        (true, false) => {
                            return Err(SpecError::UnsignedBinarized {
                                index,
                                op: op.op_name(),
                            })
                        }
                        (false, true) => {
                            return Err(SpecError::DanglingSign {
                                index: index - 1,
                            })
                        }
                        _ => {}
                    }
                    pending_sign = false;
                    if *cout == 0 || *ksize == 0 || *stride == 0 {
                        return Err(SpecError::BadConv {
                            index,
                            cout: *cout,
                            ksize: *ksize,
                            stride: *stride,
                        });
                    }
                    if h + 2 * pad < *ksize || w + 2 * pad < *ksize {
                        return Err(SpecError::EmptyConvOutput {
                            index,
                            ksize: *ksize,
                            stride: *stride,
                            pad: *pad,
                            found: shape,
                        });
                    }
                    let (oh, ow) =
                        out_hw(h, w, *ksize, *ksize, *stride, *pad);
                    if oh == 0 || ow == 0 {
                        return Err(SpecError::EmptyConvOutput {
                            index,
                            ksize: *ksize,
                            stride: *stride,
                            pad: *pad,
                            found: shape,
                        });
                    }
                    nconv += 1;
                    shape = Shape::Image { c: *cout, h: oh, w: ow };
                    awaiting_bn =
                        Some((format!("conv{nconv}"), true, false));
                }
                LayerSpec::MaxPool2 => {
                    if pending_sign {
                        return Err(SpecError::DanglingSign {
                            index: index - 1,
                        });
                    }
                    // Only between a conv and that conv's batchnorm.
                    match awaiting_bn.as_mut() {
                        Some(slot) if slot.1 && !slot.2 => slot.2 = true,
                        _ => {
                            return Err(SpecError::DanglingPool { index })
                        }
                    }
                    let Shape::Image { c, h, w } = shape else {
                        return Err(SpecError::ExpectsImage {
                            index,
                            op: op.op_name(),
                            found: shape,
                        });
                    };
                    if h % 2 != 0 || w % 2 != 0 {
                        return Err(SpecError::OddPool { index, h, w });
                    }
                    shape = Shape::Image { c, h: h / 2, w: w / 2 };
                }
                LayerSpec::BatchNorm => {
                    if pending_sign {
                        return Err(SpecError::DanglingSign {
                            index: index - 1,
                        });
                    }
                    if awaiting_bn.take().is_none() {
                        return Err(SpecError::DanglingBatchNorm { index });
                    }
                }
                LayerSpec::Sign => {
                    if let Some((layer, _, _)) = awaiting_bn.take() {
                        return Err(SpecError::MissingBatchNorm {
                            index,
                            layer,
                        });
                    }
                    if pending_sign {
                        return Err(SpecError::DanglingSign {
                            index: index - 1,
                        });
                    }
                    pending_sign = true;
                }
                LayerSpec::Flatten => {
                    if pending_sign {
                        return Err(SpecError::DanglingSign {
                            index: index - 1,
                        });
                    }
                    if let Some((layer, _, _)) = awaiting_bn.take() {
                        return Err(SpecError::MissingBatchNorm {
                            index,
                            layer,
                        });
                    }
                    let Shape::Image { c, h, w } = shape else {
                        return Err(SpecError::ExpectsImage {
                            index,
                            op: op.op_name(),
                            found: shape,
                        });
                    };
                    shape = Shape::Rows { f: c * h * w };
                }
                LayerSpec::Linear { dout, binarized } => {
                    if let Some((layer, _, _)) = awaiting_bn.take() {
                        return Err(SpecError::MissingBatchNorm {
                            index,
                            layer,
                        });
                    }
                    let Shape::Rows { .. } = shape else {
                        return Err(SpecError::ExpectsRows { index });
                    };
                    match (*binarized && signs, pending_sign) {
                        (true, false) => {
                            return Err(SpecError::UnsignedBinarized {
                                index,
                                op: op.op_name(),
                            })
                        }
                        (false, true) => {
                            return Err(SpecError::DanglingSign {
                                index: index - 1,
                            })
                        }
                        _ => {}
                    }
                    pending_sign = false;
                    if *dout == 0 {
                        return Err(SpecError::BadLinear { index });
                    }
                    nfc += 1;
                    shape = Shape::Rows { f: *dout };
                    awaiting_bn =
                        Some((format!("fc{nfc}"), false, false));
                    last_linear_dout = Some(*dout);
                }
            }
            shapes.push(shape);
        }
        if pending_sign {
            return Err(SpecError::DanglingSign {
                index: layers.len() - 1,
            });
        }
        if let Some((layer, _, _)) = awaiting_bn {
            return Err(SpecError::MissingBatchNorm {
                index: layers.len(),
                layer,
            });
        }
        // The walk above guarantees the net ends right after the final
        // linear's batchnorm iff a linear exists at all; convs can't
        // follow it (Flatten is one-way).
        let Some(classes) = last_linear_dout else {
            return Err(SpecError::NoFinalLinear);
        };
        if !matches!(layers.last(), Some(LayerSpec::BatchNorm)) {
            return Err(SpecError::NoFinalLinear);
        }
        if !matches!(shape, Shape::Rows { .. }) {
            return Err(SpecError::NoFinalLinear);
        }
        Ok(Self { input, classes, scheme, layers, shapes })
    }

    /// [`NetSpec::new`] plus a cross-check that the final linear width
    /// equals `classes` — the constructor the BKW2 reader uses, since
    /// the file carries the class count redundantly.
    pub fn with_classes(
        input: (usize, usize, usize),
        classes: usize,
        layers: Vec<LayerSpec>,
    ) -> Result<Self, SpecError> {
        Self::with_classes_scheme(input, classes, layers,
                                  QuantScheme::SignSign)
    }

    /// [`NetSpec::with_classes`] under an explicit [`QuantScheme`] —
    /// the constructor the BKW2 reader uses when the file carries a
    /// scheme section.
    pub fn with_classes_scheme(
        input: (usize, usize, usize),
        classes: usize,
        layers: Vec<LayerSpec>,
        scheme: QuantScheme,
    ) -> Result<Self, SpecError> {
        let spec = Self::new_with_scheme(input, layers, scheme)?;
        if spec.classes != classes {
            return Err(SpecError::ClassMismatch {
                dout: spec.classes,
                classes,
            });
        }
        Ok(spec)
    }

    /// Start an ergonomic builder from the input shape (C, H, W).
    pub fn builder(input: (usize, usize, usize)) -> NetSpecBuilder {
        NetSpecBuilder {
            input,
            layers: Vec::new(),
            weighted: 0,
            flattened: false,
            scheme: QuantScheme::SignSign,
            error: None,
        }
    }

    /// Synthesize the legacy CIFAR-net spec from a BKW1 `meta.widths`
    /// vector `[c1..c6, f1, f2, classes]` — six 3x3/s1/p1 convs (the
    /// first real-input, pools after conv2/4/6), three binarized fcs.
    /// This is how BKW1 files keep loading unchanged: the spec they
    /// never stored is rebuilt from the widths they did.
    pub fn from_widths(widths: &[u32]) -> Result<Self, SpecError> {
        if widths.len() != 9 {
            return Err(SpecError::LegacyWidths(format!(
                "{} entries (expected 9)",
                widths.len()
            )));
        }
        let w: Vec<usize> = widths.iter().map(|&x| x as usize).collect();
        if w[4] != w[5] {
            // python/compile/model.py derives fc1's input width from
            // widths[4] while conv6's output is widths[5]; unequal
            // values would silently disagree with the exporter.
            return Err(SpecError::LegacyWidths(format!(
                "c5 ({}) != c6 ({})",
                w[4], w[5]
            )));
        }
        let mut layers = Vec::new();
        for (i, &cout) in w[..6].iter().enumerate() {
            if i != 0 {
                layers.push(LayerSpec::Sign);
            }
            layers.push(LayerSpec::Conv2d {
                cout,
                ksize: 3,
                stride: 1,
                pad: 1,
                binarized: i != 0,
            });
            if i % 2 == 1 {
                layers.push(LayerSpec::MaxPool2);
            }
            layers.push(LayerSpec::BatchNorm);
        }
        layers.push(LayerSpec::Flatten);
        for &dout in &w[6..9] {
            layers.push(LayerSpec::Sign);
            layers.push(LayerSpec::Linear { dout, binarized: true });
            layers.push(LayerSpec::BatchNorm);
        }
        Self::with_classes((3, 32, 32), w[8], layers)
    }

    /// Input shape (C, H, W).
    pub fn input(&self) -> (usize, usize, usize) {
        self.input
    }

    /// Output class count (the final linear width).
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// The net-level quantization scheme.
    pub fn scheme(&self) -> QuantScheme {
        self.scheme
    }

    /// The validated op list, in execution order.
    pub fn layers(&self) -> &[LayerSpec] {
        &self.layers
    }

    /// Shape of the activation AFTER each op (parallel to
    /// [`NetSpec::layers`]).
    pub fn output_shapes(&self) -> &[Shape] {
        &self.shapes
    }

    /// Canonical weight-file key prefix per op: `Some("conv<k>")` /
    /// `Some("fc<k>")` for the k-th conv/linear, `Some("bn_<layer>")`
    /// for each batchnorm (keyed to its owning weighted layer), `None`
    /// for structural ops.  Both the rust loader and the python
    /// exporter derive names this way, so they can never drift.
    pub fn layer_names(&self) -> Vec<Option<String>> {
        let (mut nconv, mut nfc) = (0usize, 0usize);
        let mut owner = String::new();
        self.layers
            .iter()
            .map(|op| match op {
                LayerSpec::Conv2d { .. } => {
                    nconv += 1;
                    owner = format!("conv{nconv}");
                    Some(owner.clone())
                }
                LayerSpec::Linear { .. } => {
                    nfc += 1;
                    owner = format!("fc{nfc}");
                    Some(owner.clone())
                }
                LayerSpec::BatchNorm => Some(format!("bn_{owner}")),
                _ => None,
            })
            .collect()
    }

    /// Total learnable parameter count (weights + folded BN affines).
    pub fn param_count(&self) -> usize {
        let (convs, fcs) = self.blocks();
        let conv: usize = convs.iter().map(|s| s.cout * s.k()).sum();
        let fc: usize = fcs.iter().map(|s| s.din * s.dout).sum();
        let bn: usize = convs.iter().map(|s| 2 * s.cout).sum::<usize>()
            + fcs.iter().map(|s| 2 * s.dout).sum::<usize>();
        let alpha: usize = if self.scheme.has_alpha() {
            convs.iter().filter(|s| s.binarized).map(|s| s.cout).sum::<usize>()
                + fcs.iter().filter(|s| s.binarized).map(|s| s.dout).sum::<usize>()
        } else {
            0
        };
        conv + fc + bn + alpha
    }

    /// The weighted-layer view the engine loader and plan lowering
    /// walk: conv blocks (with their pool flags) and fc blocks, with
    /// all derived dims (cin/din) resolved from the shape trace.
    pub(crate) fn blocks(&self) -> (Vec<ConvBlock>, Vec<FcBlock>) {
        let mut convs = Vec::new();
        let mut fcs = Vec::new();
        let (ic, ih, iw) = self.input;
        let mut before = Shape::Image { c: ic, h: ih, w: iw };
        for (op, after) in self.layers.iter().zip(&self.shapes) {
            match op {
                LayerSpec::Conv2d { cout, ksize, stride, pad, binarized } => {
                    let Shape::Image { c, .. } = before else {
                        unreachable!("validated spec");
                    };
                    convs.push(ConvBlock {
                        name: format!("conv{}", convs.len() + 1),
                        cin: c,
                        cout: *cout,
                        ksize: *ksize,
                        stride: *stride,
                        pad: *pad,
                        pool: false,
                        binarized: *binarized,
                    });
                }
                LayerSpec::MaxPool2 => {
                    convs
                        .last_mut()
                        .expect("validated spec: pool follows a conv")
                        .pool = true;
                }
                LayerSpec::Linear { dout, binarized } => {
                    let Shape::Rows { f } = before else {
                        unreachable!("validated spec");
                    };
                    fcs.push(FcBlock {
                        name: format!("fc{}", fcs.len() + 1),
                        din: f,
                        dout: *dout,
                        binarized: *binarized,
                    });
                }
                _ => {}
            }
            before = *after;
        }
        (convs, fcs)
    }
}

/// Fluent constructor for [`NetSpec`] — inserts the `Sign` /
/// `BatchNorm` / `Flatten` plumbing the grammar requires, and follows
/// the XNOR-Net convention that the FIRST weighted layer keeps a
/// real-valued input while every later one is binarized (override with
/// the `*_opts` methods).  Errors (bad geometry, `.pool()` without a
/// conv, no final linear) surface as typed [`SpecError`]s from
/// [`NetSpecBuilder::build`], never panics.
#[derive(Debug, Clone)]
pub struct NetSpecBuilder {
    input: (usize, usize, usize),
    layers: Vec<LayerSpec>,
    weighted: usize,
    flattened: bool,
    scheme: QuantScheme,
    error: Option<SpecError>,
}

impl NetSpecBuilder {
    /// Append a conv block (`ksize`/2 padding, stride 1); binarized iff
    /// it is not the first weighted layer.
    pub fn conv(self, cout: usize, ksize: usize) -> Self {
        let binarized = self.weighted > 0;
        self.conv_opts(cout, ksize, 1, ksize / 2, binarized)
    }

    /// Append a conv block with every knob explicit.
    pub fn conv_opts(
        mut self,
        cout: usize,
        ksize: usize,
        stride: usize,
        pad: usize,
        binarized: bool,
    ) -> Self {
        if self.flattened && self.error.is_none() {
            self.error = Some(SpecError::Builder(
                "conv after a linear/flatten".to_string(),
            ));
        }
        if binarized {
            self.layers.push(LayerSpec::Sign);
        }
        self.layers.push(LayerSpec::Conv2d {
            cout,
            ksize,
            stride,
            pad,
            binarized,
        });
        self.layers.push(LayerSpec::BatchNorm);
        self.weighted += 1;
        self
    }

    /// 2x2 max-pool after the last conv (before its batchnorm).
    pub fn pool(mut self) -> Self {
        // The conv block was pushed as [.., Conv2d, BatchNorm]; the
        // pool sits between them.
        let fits = self.layers.len() >= 2
            && matches!(self.layers.last(), Some(LayerSpec::BatchNorm))
            && matches!(
                self.layers.get(self.layers.len() - 2),
                Some(LayerSpec::Conv2d { .. })
            );
        if fits {
            let at = self.layers.len() - 1;
            self.layers.insert(at, LayerSpec::MaxPool2);
        } else if self.error.is_none() {
            self.error = Some(SpecError::Builder(
                ".pool() must directly follow .conv()".to_string(),
            ));
        }
        self
    }

    /// Append a fully-connected block (a `Flatten` is inserted first if
    /// the net is still in the image domain); binarized iff it is not
    /// the first weighted layer.
    pub fn linear(self, dout: usize) -> Self {
        let binarized = self.weighted > 0;
        self.linear_opts(dout, binarized)
    }

    /// Append a fully-connected block with the binarization explicit.
    pub fn linear_opts(mut self, dout: usize, binarized: bool) -> Self {
        if !self.flattened {
            self.layers.push(LayerSpec::Flatten);
            self.flattened = true;
        }
        if binarized {
            self.layers.push(LayerSpec::Sign);
        }
        self.layers.push(LayerSpec::Linear { dout, binarized });
        self.layers.push(LayerSpec::BatchNorm);
        self.weighted += 1;
        self
    }

    /// Select the net-level [`QuantScheme`] (default
    /// [`QuantScheme::SignSign`]).  May be called at any point in the
    /// chain: the builder's `Sign` plumbing is reconciled at
    /// [`NetSpecBuilder::build`], so schemes with real-valued
    /// activations simply drop the `Sign` ops the grammar no longer
    /// wants.
    pub fn scheme(mut self, scheme: QuantScheme) -> Self {
        self.scheme = scheme;
        self
    }

    /// Validate and produce the [`NetSpec`]; the class count is the
    /// final linear width.
    pub fn build(self) -> Result<NetSpec, SpecError> {
        if let Some(e) = self.error {
            return Err(e);
        }
        let mut layers = self.layers;
        if !self.scheme.signs_activations() {
            // The builder emits Sign ops only directly before binarized
            // weighted layers, so dropping them all yields exactly the
            // sign-free grammar these schemes validate against.
            layers.retain(|l| !matches!(l, LayerSpec::Sign));
        }
        NetSpec::new_with_scheme(self.input, layers, self.scheme)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: [u32; 9] = [128, 128, 256, 256, 512, 512, 1024, 1024, 10];

    #[test]
    fn full_scale_matches_paper() {
        let spec = NetSpec::from_widths(&FULL).unwrap();
        let (convs, fcs) = spec.blocks();
        assert_eq!(convs.len(), 6);
        assert_eq!(fcs.len(), 3);
        assert_eq!(convs[0].cin, 3);
        assert!(!convs[0].binarized);
        assert!(convs[1].binarized && convs[1].pool);
        assert_eq!(convs[5].cout, 512);
        assert_eq!(fcs[0].din, 512 * 4 * 4);
        assert_eq!(fcs[2].dout, 10);
        assert_eq!(spec.classes(), 10);
        let p = spec.param_count();
        assert!((13_000_000..16_000_000).contains(&p), "{p}");
    }

    #[test]
    fn small_scale() {
        let spec = NetSpec::from_widths(&[32, 32, 64, 64, 128, 128, 256,
                                          256, 10])
            .unwrap();
        let (convs, fcs) = spec.blocks();
        assert_eq!(fcs[0].din, 128 * 16);
        assert_eq!(fcs[1].din, 256);
        assert_eq!(convs[2].k(), 32 * 9);
    }

    #[test]
    fn rejects_bad_widths() {
        assert!(matches!(NetSpec::from_widths(&[1, 2, 3]),
                         Err(SpecError::LegacyWidths(_))));
        // c5 != c6 disagrees with the python exporter's fc1 width.
        assert!(matches!(
            NetSpec::from_widths(&[8, 8, 8, 8, 8, 16, 8, 8, 10]),
            Err(SpecError::LegacyWidths(_))
        ));
    }

    #[test]
    fn builder_matches_from_widths() {
        let built = NetSpec::builder((3, 32, 32))
            .conv(4, 3)
            .conv(4, 3)
            .pool()
            .conv(6, 3)
            .conv(6, 3)
            .pool()
            .conv(8, 3)
            .conv(8, 3)
            .pool()
            .linear(16)
            .linear(12)
            .linear(10)
            .build()
            .unwrap();
        let legacy =
            NetSpec::from_widths(&[4, 4, 6, 6, 8, 8, 16, 12, 10]).unwrap();
        assert_eq!(built, legacy);
    }

    #[test]
    fn builder_custom_shapes() {
        let spec = NetSpec::builder((1, 28, 28))
            .conv(16, 3)
            .pool()
            .conv(32, 3)
            .pool()
            .linear(64)
            .linear(26)
            .build()
            .unwrap();
        assert_eq!(spec.input(), (1, 28, 28));
        assert_eq!(spec.classes(), 26);
        let (convs, fcs) = spec.blocks();
        assert_eq!(convs[1].cin, 16);
        assert_eq!(fcs[0].din, 32 * 7 * 7);
        assert_eq!(spec.output_shapes().last(),
                   Some(&Shape::Rows { f: 26 }));
    }

    #[test]
    fn fc_only_nets_build() {
        let spec = NetSpec::builder((1, 8, 8))
            .linear(32)
            .linear(5)
            .build()
            .unwrap();
        let (convs, fcs) = spec.blocks();
        assert!(convs.is_empty());
        assert_eq!(fcs[0].din, 64);
        assert!(!fcs[0].binarized, "first weighted layer stays real");
        assert!(fcs[1].binarized);
    }

    #[test]
    fn validation_rejects_malformed_specs() {
        use LayerSpec::*;
        let b = |l| NetSpec::new((3, 8, 8), l);
        // binarized layer without a sign
        assert!(matches!(
            b(vec![Conv2d { cout: 4, ksize: 3, stride: 1, pad: 1,
                            binarized: true },
                   BatchNorm, Flatten, Sign,
                   Linear { dout: 2, binarized: true }, BatchNorm]),
            Err(SpecError::UnsignedBinarized { index: 0, .. })
        ));
        // sign feeding a non-binarized layer
        assert!(matches!(
            b(vec![Sign,
                   Conv2d { cout: 4, ksize: 3, stride: 1, pad: 1,
                            binarized: false },
                   BatchNorm, Flatten, Sign,
                   Linear { dout: 2, binarized: true }, BatchNorm]),
            Err(SpecError::DanglingSign { index: 0 })
        ));
        // conv without its batchnorm
        assert!(matches!(
            b(vec![Conv2d { cout: 4, ksize: 3, stride: 1, pad: 1,
                            binarized: false },
                   Flatten, Sign,
                   Linear { dout: 2, binarized: true }, BatchNorm]),
            Err(SpecError::MissingBatchNorm { .. })
        ));
        // pool on odd dims
        assert!(matches!(
            NetSpec::new(
                (3, 7, 7),
                vec![Conv2d { cout: 4, ksize: 3, stride: 1, pad: 1,
                              binarized: false },
                     MaxPool2, BatchNorm, Flatten, Sign,
                     Linear { dout: 2, binarized: true }, BatchNorm]
            ),
            Err(SpecError::OddPool { .. })
        ));
        // linear before flatten
        assert!(matches!(
            b(vec![Linear { dout: 2, binarized: false }, BatchNorm]),
            Err(SpecError::ExpectsRows { index: 0 })
        ));
        // net not ending in a linear
        assert!(matches!(
            b(vec![Conv2d { cout: 4, ksize: 3, stride: 1, pad: 1,
                            binarized: false },
                   BatchNorm]),
            Err(SpecError::NoFinalLinear)
        ));
        // empty conv output
        assert!(matches!(
            b(vec![Conv2d { cout: 4, ksize: 9, stride: 1, pad: 0,
                            binarized: false },
                   BatchNorm, Flatten, Sign,
                   Linear { dout: 2, binarized: true }, BatchNorm]),
            Err(SpecError::EmptyConvOutput { .. })
        ));
        // zero input dim
        assert!(matches!(
            NetSpec::new((0, 8, 8), vec![Flatten, Sign,
                                         Linear { dout: 2,
                                                  binarized: true },
                                         BatchNorm]),
            Err(SpecError::ZeroInput(..))
        ));
    }

    #[test]
    fn with_classes_cross_checks() {
        use LayerSpec::*;
        let layers = vec![Flatten,
                          Linear { dout: 5, binarized: false },
                          BatchNorm];
        assert!(NetSpec::with_classes((1, 2, 2), 5, layers.clone()).is_ok());
        assert!(matches!(
            NetSpec::with_classes((1, 2, 2), 7, layers),
            Err(SpecError::ClassMismatch { dout: 5, classes: 7 })
        ));
    }

    #[test]
    fn layer_names_are_canonical() {
        let spec = NetSpec::builder((3, 8, 8))
            .conv(4, 3)
            .pool()
            .linear(6)
            .linear(2)
            .build()
            .unwrap();
        let names = spec.layer_names();
        let got: Vec<&str> = names
            .iter()
            .filter_map(|n| n.as_deref())
            .collect();
        assert_eq!(got, ["conv1", "bn_conv1", "fc1", "bn_fc1", "fc2",
                         "bn_fc2"]);
    }

    #[test]
    fn builder_pool_without_conv_errors() {
        assert!(matches!(
            NetSpec::builder((3, 8, 8)).pool().linear(2).build(),
            Err(SpecError::Builder(_))
        ));
    }

    #[test]
    fn scheme_names_and_wire_bytes_are_pinned() {
        let want = [("sign_sign", 0u8), ("xnor_alpha", 1),
                    ("binary_weight", 2), ("ternary_weight", 3)];
        assert_eq!(QuantScheme::ALL.len(), want.len());
        for (s, (name, byte)) in QuantScheme::ALL.iter().zip(want) {
            assert_eq!(s.name(), name);
            assert_eq!(s.wire_byte(), byte);
            assert_eq!(QuantScheme::from_wire_byte(byte), Some(*s));
        }
        assert_eq!(QuantScheme::from_wire_byte(4), None);
        assert_eq!(QuantScheme::default(), QuantScheme::SignSign);
        assert!(QuantScheme::SignSign.is_default());
        assert!(!QuantScheme::XnorAlpha.is_default());
    }

    #[test]
    fn default_constructors_stay_sign_sign() {
        let spec = NetSpec::from_widths(&FULL).unwrap();
        assert_eq!(spec.scheme(), QuantScheme::SignSign);
        let spec = NetSpec::builder((1, 8, 8)).linear(5).build().unwrap();
        assert_eq!(spec.scheme(), QuantScheme::SignSign);
    }

    #[test]
    fn builder_selects_schemes() {
        for scheme in QuantScheme::ALL {
            let spec = NetSpec::builder((3, 8, 8))
                .conv(4, 3)
                .pool()
                .conv(4, 3)
                .linear(6)
                .linear(2)
                .scheme(scheme)
                .build()
                .unwrap();
            assert_eq!(spec.scheme(), scheme);
            let n_signs = spec
                .layers()
                .iter()
                .filter(|l| matches!(l, LayerSpec::Sign))
                .count();
            // conv2, fc1, fc2 are binarized: three signs under
            // sign-consuming schemes, none under binary_weight.
            if scheme.signs_activations() {
                assert_eq!(n_signs, 3, "{scheme}");
            } else {
                assert_eq!(n_signs, 0, "{scheme}");
            }
            // binarized flags are scheme-independent
            let (convs, fcs) = spec.blocks();
            assert!(!convs[0].binarized && convs[1].binarized);
            assert!(fcs[0].binarized && fcs[1].binarized);
        }
    }

    #[test]
    fn binary_weight_grammar_forbids_sign_ops() {
        use LayerSpec::*;
        // a Sign can never be consumed when activations stay real
        assert!(matches!(
            NetSpec::new_with_scheme(
                (1, 2, 2),
                vec![Flatten, Sign, Linear { dout: 2, binarized: true },
                     BatchNorm],
                QuantScheme::BinaryWeight,
            ),
            Err(SpecError::DanglingSign { index: 1 })
        ));
        // ...and a binarized layer needs no Sign under binary_weight
        let spec = NetSpec::new_with_scheme(
            (1, 2, 2),
            vec![Flatten, Linear { dout: 2, binarized: true }, BatchNorm],
            QuantScheme::BinaryWeight,
        )
        .unwrap();
        assert_eq!(spec.scheme(), QuantScheme::BinaryWeight);
        // ...but still needs one under every sign-consuming scheme
        assert!(matches!(
            NetSpec::new_with_scheme(
                (1, 2, 2),
                vec![Flatten, Linear { dout: 2, binarized: true },
                     BatchNorm],
                QuantScheme::TernaryWeight,
            ),
            Err(SpecError::UnsignedBinarized { index: 1, .. })
        ));
    }

    #[test]
    fn alpha_counts_as_parameters() {
        let base = NetSpec::builder((1, 8, 8)).linear(6).linear(2).build()
            .unwrap();
        let with_alpha = NetSpec::builder((1, 8, 8))
            .linear(6)
            .linear(2)
            .scheme(QuantScheme::XnorAlpha)
            .build()
            .unwrap();
        // only fc2 is binarized -> 2 extra alpha scalars
        assert_eq!(with_alpha.param_count(), base.param_count() + 2);
    }
}
