//! Read-only memory-mapped file buffers (zero-copy weight loading).
//!
//! [`Mmap::open`] maps a whole file `PROT_READ`/`MAP_PRIVATE`.  The
//! container carries no `libc` crate, so the two syscalls used are
//! declared inline on unix; every other platform — and any file the
//! kernel refuses to map — falls back to a plain heap read, so callers
//! never need a platform branch.
//!
//! The mapping is immutable and page-cache backed: a
//! [`WeightFile`](super::WeightFile) opened through
//! [`WeightFile::open_mmap`](super::WeightFile::open_mmap) costs
//! address space, not resident heap, until its pages are touched — the
//! property the model registry's cold-mount path relies on to keep
//! hundreds of unmounted-but-ready models cheap.

use std::io;
use std::path::Path;

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    // Values shared by every unix the toolchain targets here (linux,
    // macOS): PROT_READ = 0x1, MAP_PRIVATE = 0x2.
    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: isize,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

enum Backing {
    /// A live kernel mapping (unmapped on drop).
    #[cfg(unix)]
    Mapped { ptr: *const u8, len: usize },
    /// Heap fallback: non-unix targets, empty files, or a refused map.
    Heap(Vec<u8>),
}

/// An immutable byte buffer backed by a file mapping (with a heap
/// fallback).  Dereferences to `&[u8]`.
pub struct Mmap {
    backing: Backing,
}

// SAFETY: the mapping is PROT_READ and never written after `open`;
// the fallback is an owned Vec that is never mutated.  Only shared
// references to the bytes are ever handed out.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `path` read-only.  Falls back to reading the file onto the
    /// heap when mapping is unavailable (non-unix, empty file, or the
    /// kernel refusing the map), so the result is always usable.
    pub fn open(path: impl AsRef<Path>) -> io::Result<Self> {
        let path = path.as_ref();
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let file = std::fs::File::open(path)?;
            let len = file.metadata()?.len() as usize;
            if len == 0 {
                return Ok(Self { backing: Backing::Heap(Vec::new()) });
            }
            // SAFETY: the fd is open and `len` is the file's current
            // size; closing the fd after mmap keeps the mapping live
            // (POSIX), so the File may drop at the end of this scope.
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            // MAP_FAILED is (void*)-1; on it, fall through to the heap
            // read below rather than failing the load.
            if ptr as usize != usize::MAX && !ptr.is_null() {
                return Ok(Self {
                    backing: Backing::Mapped { ptr: ptr as *const u8, len },
                });
            }
        }
        Ok(Self { backing: Backing::Heap(std::fs::read(path)?) })
    }

    /// The mapped bytes.
    pub fn as_slice(&self) -> &[u8] {
        match &self.backing {
            #[cfg(unix)]
            // SAFETY: ptr/len came from a successful mmap that lives
            // until Drop, and the mapping is never mutated.
            Backing::Mapped { ptr, len } => unsafe {
                std::slice::from_raw_parts(*ptr, *len)
            },
            Backing::Heap(v) => v,
        }
    }

    /// Length of the buffer in bytes.
    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    /// Whether the buffer is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the bytes live in a kernel mapping (false: heap
    /// fallback).
    pub fn is_mapped(&self) -> bool {
        match &self.backing {
            #[cfg(unix)]
            Backing::Mapped { .. } => true,
            Backing::Heap(_) => false,
        }
    }
}

impl std::ops::Deref for Mmap {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mmap")
            .field("len", &self.len())
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Backing::Mapped { ptr, len } = self.backing {
            // SAFETY: exactly the region mmap returned, unmapped once.
            unsafe {
                sys::munmap(ptr as *mut std::os::raw::c_void, len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_a_file_round_trip() {
        let dir = std::env::temp_dir()
            .join(format!("bk-mmap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("blob.bin");
        let payload: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::write(&path, &payload).unwrap();
        let map = Mmap::open(&path).unwrap();
        assert_eq!(map.len(), payload.len());
        assert_eq!(&map[..], &payload[..]);
        drop(map);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_file_is_fine() {
        let dir = std::env::temp_dir()
            .join(format!("bk-mmap-empty-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.bin");
        std::fs::write(&path, b"").unwrap();
        let map = Mmap::open(&path).unwrap();
        assert!(map.is_empty());
        assert!(!map.is_mapped());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(Mmap::open("/definitely/not/here.bin").is_err());
    }

    #[test]
    fn shared_across_threads() {
        let dir = std::env::temp_dir()
            .join(format!("bk-mmap-thr-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("blob.bin");
        std::fs::write(&path, vec![7u8; 4096]).unwrap();
        let map = std::sync::Arc::new(Mmap::open(&path).unwrap());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = std::sync::Arc::clone(&map);
                std::thread::spawn(move || {
                    m.iter().map(|&b| b as usize).sum::<usize>()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 7 * 4096);
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
