//! The BNN model layer: the [`NetSpec`] architecture IR, BKW1/BKW2
//! weights, the native inference engine (the Table-2 "CPU" arm), and
//! its compiled plan/session execution path.
//!
//! Serving flow: describe (or load) a [`NetSpec`], load a
//! [`BnnEngine`], compile a [`Plan`] once per (kernel, max_batch),
//! derive one [`Session`] per worker thread, and call [`Session::run`]
//! per batch — zero heap allocation in steady state.  The engine is
//! architecture-generic: any spec the IR validates (arbitrary conv
//! stacks, fc-only nets, non-square inputs, any class count) plans and
//! runs on every kernel arm through this Plan/Session API — and the
//! HTTP front-end in `server`/`coordinator` is equally generic, since
//! it reads every model's shape contract off its compiled [`Plan`]
//! ([`Plan::input_shape`] / [`Plan::classes`] / [`Plan::labels`]).

pub mod bnn;
pub mod calib;
pub mod format;
pub mod mmap;
pub mod plan;
pub mod spec;

pub use bnn::{label_for, BnnEngine, EngineKernel};
pub use calib::CalibCache;
pub use format::{Dtype, FormatError, WeightFile, WeightTensor};
pub use mmap::Mmap;
pub use plan::{Plan, Session};
pub use spec::{LayerSpec, NetSpec, NetSpecBuilder, QuantScheme, Shape,
               SpecError};
