//! The BNN model layer: architecture config, BKW1 weights, the native
//! inference engine (the Table-2 "CPU" arm), and its compiled
//! plan/session execution path.
//!
//! Serving flow: load a [`BnnEngine`], compile a [`Plan`] once per
//! (kernel, max_batch), derive one [`Session`] per worker thread, and
//! call [`Session::run`] per batch — zero heap allocation in steady
//! state.

pub mod bnn;
pub mod config;
pub mod format;
pub mod plan;

pub use bnn::{BnnEngine, EngineKernel};
pub use config::{ConvSpec, FcSpec, ModelConfig};
pub use format::{Dtype, WeightFile, WeightTensor};
pub use plan::{Plan, Session};
