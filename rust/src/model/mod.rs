//! The BNN model layer: the [`NetSpec`] architecture IR, BKW1/BKW2
//! weights, the native inference engine (the Table-2 "CPU" arm), and
//! its compiled plan/session execution path.
//!
//! Serving flow: describe (or load) a [`NetSpec`], load a
//! [`BnnEngine`], compile a [`Plan`] once per (kernel, max_batch),
//! derive one [`Session`] per worker thread, and call [`Session::run`]
//! per batch — zero heap allocation in steady state.  The engine is
//! architecture-generic: any spec the IR validates (arbitrary conv
//! stacks, fc-only nets, non-square inputs, any class count) plans and
//! runs on every kernel arm through this Plan/Session API.  (The HTTP
//! front-end in `server`/`coordinator` still assumes the paper's
//! 3x32x32/10-class request shape and guards for it at startup.)

pub mod bnn;
pub mod format;
pub mod plan;
pub mod spec;

pub use bnn::{BnnEngine, EngineKernel};
pub use format::{Dtype, FormatError, WeightFile, WeightTensor};
pub use plan::{Plan, Session};
pub use spec::{LayerSpec, NetSpec, NetSpecBuilder, Shape, SpecError};
