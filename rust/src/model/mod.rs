//! The BNN model layer: architecture config, BKW1 weights, and the
//! native inference engine (the Table-2 "CPU" arm).

pub mod bnn;
pub mod config;
pub mod format;

pub use bnn::{BnnEngine, EngineKernel};
pub use config::{ConvSpec, FcSpec, ModelConfig};
pub use format::{Dtype, WeightFile, WeightTensor};
