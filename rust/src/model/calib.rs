//! Persistent xnor-gemm calibration cache.
//!
//! `BITKERNEL_CALIBRATE=1` makes plan compilation microbench each
//! distinct `Auto` gemm shape ([`XnorImpl::calibrate`]) instead of
//! using the shape heuristic.  Before this cache every plan build paid
//! that cost again — including rebuilding the *same* model on
//! `PUT /models/{name}` reloads, lazy-mount first requests, and LRU
//! re-promotions, where the answer cannot have changed.  Now the
//! result of each microbench lands in a versioned sidecar file keyed
//! by
//!
//! * a **CPU fingerprint** (arch + detected SIMD tiers + thread
//!   count — a cache copied to different hardware is ignored, not
//!   trusted),
//! * the **impl set** (the candidate arms [`XnorImpl::calibrate`]
//!   races — a new kernel tier invalidates old winners so it gets a
//!   chance to win), and
//! * the **D/K/N gemm shape**,
//!
//! so a warm cache makes plan builds perform **zero** microbenches.
//! An in-memory layer in front of the file dedupes within the process
//! even when persistence is disabled.
//!
//! Env knobs (read once, at first use of the global cache):
//!
//! * `BITKERNEL_CALIB_CACHE=<path>` — sidecar file location.  Default:
//!   `$XDG_CACHE_HOME/bitkernel/calib-v1` (or `$HOME/.cache/...`,
//!   or the temp dir).  `0`/`off` disables persistence entirely
//!   (in-memory dedupe only).
//! * `BITKERNEL_CALIB_INVALIDATE=1` — wipe the sidecar before first
//!   use (the explicit invalidation path; [`CalibCache::invalidate`]
//!   is the programmatic one).
//!
//! The file is line-oriented UTF-8 so it diffs and greps:
//!
//! ```text
//! # bitkernel calib v1
//! x86_64|avx2|t8|blocked,...,threaded8|64x288x1024|threaded8
//! ```
//!
//! Lines whose version/fingerprint/impl-set don't match the running
//! process are skipped (never deleted — one file can serve
//! heterogeneous hosts on a shared home dir).  Appends are line-atomic
//! (`O_APPEND`), and every write is best-effort: an unwritable cache
//! degrades to per-process dedupe, never to an error.
//!
//! `bitkernel_calibrations_total` on `/metrics` counts the microbenches
//! this process actually ran — a reload hammering the cache holds it
//! flat, which is exactly what the lifecycle tests pin.

use std::collections::HashMap;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::bitops::{avx2_available, avx512bw_available,
                    avx512_vpopcnt_available, XnorImpl};

/// Cache format version — bump on any change to the line layout or
/// the meaning of a fingerprint component; old files are then ignored
/// wholesale.
const VERSION: &str = "v1";

/// Microbenches actually run by this process (any cache instance).
/// Exposed as `bitkernel_calibrations_total`; a warm cache keeps this
/// flat across plan rebuilds.
static CALIBRATIONS: AtomicU64 = AtomicU64::new(0);

/// Total microbenches run process-wide (the
/// `bitkernel_calibrations_total` counter).
pub fn calibrations_total() -> u64 {
    CALIBRATIONS.load(Ordering::Relaxed)
}

/// Prometheus-style exposition of the calibration counter (appended to
/// `/metrics` by the service layer).
pub fn render_metrics() -> String {
    crate::coordinator::Metrics::render_series(
        "bitkernel_calibrations_total",
        "",
        calibrations_total(),
    )
}

/// The hardware identity calibration results are valid for: arch, the
/// detected SIMD gemm tiers, and the thread count `Auto`/`Threaded`
/// would use.  Any of these changing (new machine, container with a
/// different cpuset) makes old winners meaningless.
pub fn cpu_fingerprint() -> String {
    let mut tiers = Vec::new();
    if avx512_vpopcnt_available() {
        tiers.push("avx512vpopcntdq");
    }
    if avx512bw_available() {
        tiers.push("avx512bw");
    }
    if avx2_available() {
        tiers.push("avx2");
    }
    if tiers.is_empty() {
        tiers.push("portable");
    }
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    format!("{}|{}|t{threads}", std::env::consts::ARCH, tiers.join("+"))
}

/// The candidate set a cached winner was picked from.  Derived from
/// [`XnorImpl::ALL_SINGLE`] so adding a kernel arm automatically
/// invalidates every cached choice (the new arm deserves a race).
pub fn impl_set() -> String {
    XnorImpl::ALL_SINGLE
        .iter()
        .map(|i| i.name().into_owned())
        .collect::<Vec<_>>()
        .join(",")
}

/// One calibration cache: an in-memory shape map in front of an
/// optional sidecar file.  The process-wide instance is [`global`];
/// tests build their own with explicit paths (no env mutation).
pub struct CalibCache {
    path: Option<PathBuf>,
    cpu: String,
    impls: String,
    mem: Mutex<HashMap<(usize, usize, usize), XnorImpl>>,
}

impl CalibCache {
    /// Open a cache over `path` (`None` = in-memory only), loading
    /// every persisted entry whose version, CPU fingerprint, and impl
    /// set match this process.  Missing or malformed files are treated
    /// as empty.
    pub fn open(path: Option<PathBuf>) -> CalibCache {
        let cache = CalibCache {
            path,
            cpu: cpu_fingerprint(),
            impls: impl_set(),
            mem: Mutex::new(HashMap::new()),
        };
        if let Some(p) = cache.path.as_deref() {
            let mut mem = cache.mem.lock().unwrap();
            for (shape, imp) in cache.load_matching(p) {
                mem.insert(shape, imp);
            }
            drop(mem);
        }
        cache
    }

    /// Parse `path`, returning only the entries valid for this
    /// process (header version + fingerprints must match).
    fn load_matching(
        &self,
        path: &Path,
    ) -> Vec<((usize, usize, usize), XnorImpl)> {
        let Ok(text) = std::fs::read_to_string(path) else {
            return Vec::new();
        };
        let mut lines = text.lines();
        let header = lines.next().unwrap_or("");
        if header.trim() != format!("# bitkernel calib {VERSION}") {
            return Vec::new();
        }
        let mut out = Vec::new();
        for line in lines {
            let Some(entry) = self.parse_line(line) else { continue };
            out.push(entry);
        }
        out
    }

    /// One entry line: `<cpu>|<impls>|<d>x<k>x<n>|<winner>`, where
    /// `<cpu>` itself contains two `|`s (arch|tiers|tN).  Returns
    /// `None` for comments, foreign fingerprints, and malformed lines.
    fn parse_line(
        &self,
        line: &str,
    ) -> Option<((usize, usize, usize), XnorImpl)> {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            return None;
        }
        let rest = line.strip_prefix(&self.cpu)?.strip_prefix('|')?;
        let rest = rest.strip_prefix(&self.impls)?.strip_prefix('|')?;
        let (shape, winner) = rest.split_once('|')?;
        let mut dims = shape.split('x');
        let d: usize = dims.next()?.parse().ok()?;
        let k: usize = dims.next()?.parse().ok()?;
        let n: usize = dims.next()?.parse().ok()?;
        if dims.next().is_some() {
            return None;
        }
        let imp = XnorImpl::from_name(winner)?;
        // `Auto` as a stored winner would recurse at plan time —
        // calibrate never returns it, so treat it as corruption.
        if imp == XnorImpl::Auto {
            return None;
        }
        Some(((d, k, n), imp))
    }

    /// Resolve a shape through the cache, running `bench` (and
    /// persisting its winner) only on a miss.
    pub fn resolve_with(
        &self,
        d: usize,
        k: usize,
        n: usize,
        bench: impl FnOnce() -> XnorImpl,
    ) -> XnorImpl {
        if let Some(&hit) = self.mem.lock().unwrap().get(&(d, k, n)) {
            return hit;
        }
        // Bench OUTSIDE the lock: concurrent plan builds of different
        // shapes shouldn't serialize on a multi-ms microbench.  Two
        // racers on the same shape both bench and the last write wins
        // — both winners are valid answers for this hardware.
        let imp = bench();
        CALIBRATIONS.fetch_add(1, Ordering::Relaxed);
        self.mem.lock().unwrap().insert((d, k, n), imp);
        self.append(d, k, n, imp);
        imp
    }

    /// Resolve a shape, microbenching via [`XnorImpl::calibrate`] on a
    /// miss — the plan-compilation entry point.
    pub fn resolve(&self, d: usize, k: usize, n: usize) -> XnorImpl {
        self.resolve_with(d, k, n, || XnorImpl::calibrate(d, k, n))
    }

    /// Best-effort append of one entry (creates the file + header on
    /// first write).  IO failure degrades to in-memory dedupe.
    fn append(&self, d: usize, k: usize, n: usize, imp: XnorImpl) {
        let Some(path) = self.path.as_deref() else { return };
        let write = || -> std::io::Result<()> {
            if let Some(dir) = path.parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)?;
                }
            }
            let fresh = !path.exists();
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)?;
            let mut line = String::new();
            if fresh {
                line.push_str(&format!("# bitkernel calib {VERSION}\n"));
            }
            line.push_str(&format!(
                "{}|{}|{d}x{k}x{n}|{}\n",
                self.cpu,
                self.impls,
                imp.name()
            ));
            f.write_all(line.as_bytes())
        };
        if let Err(e) = write() {
            crate::log_warn!(
                "calibration cache write to {} failed: {e} \
                 (continuing in-memory)",
                path.display()
            );
        }
    }

    /// Number of shapes currently cached (memory layer).
    pub fn len(&self) -> usize {
        self.mem.lock().unwrap().len()
    }

    /// True when no shape has been cached yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Explicit invalidation: clear the memory layer and delete the
    /// sidecar file, so the next resolve re-benches from scratch.
    pub fn invalidate(&self) -> std::io::Result<()> {
        self.mem.lock().unwrap().clear();
        match self.path.as_deref() {
            Some(p) => match std::fs::remove_file(p) {
                Err(e) if e.kind() != std::io::ErrorKind::NotFound => {
                    Err(e)
                }
                _ => Ok(()),
            },
            None => Ok(()),
        }
    }

    /// The sidecar path this cache persists to (`None` = memory-only).
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }
}

/// Default sidecar location: the user cache dir, falling back to the
/// system temp dir (always writable in containers).
fn default_path() -> PathBuf {
    let base = std::env::var_os("XDG_CACHE_HOME")
        .map(PathBuf::from)
        .or_else(|| {
            std::env::var_os("HOME")
                .map(|h| PathBuf::from(h).join(".cache"))
        })
        .unwrap_or_else(std::env::temp_dir);
    base.join("bitkernel").join(format!("calib-{VERSION}"))
}

/// The process-wide cache, configured from the env on first use.
pub fn global() -> &'static CalibCache {
    static GLOBAL: OnceLock<CalibCache> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let path = match std::env::var("BITKERNEL_CALIB_CACHE") {
            Ok(v) if v == "0" || v.eq_ignore_ascii_case("off") => None,
            Ok(v) if !v.is_empty() => Some(PathBuf::from(v)),
            _ => Some(default_path()),
        };
        let cache = CalibCache::open(path);
        if std::env::var_os("BITKERNEL_CALIB_INVALIDATE")
            .is_some_and(|v| v != "0")
        {
            let _ = cache.invalidate();
        }
        cache
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("bitkernel-calib-test-{name}-{}", std::process::id()))
    }

    #[test]
    fn memory_layer_dedupes_benches() {
        let cache = CalibCache::open(None);
        let runs = AtomicUsize::new(0);
        let bench = || {
            runs.fetch_add(1, Ordering::Relaxed);
            XnorImpl::Wide
        };
        assert_eq!(cache.resolve_with(4, 64, 8, bench), XnorImpl::Wide);
        // Second resolve of the same shape: zero benches.
        let again = cache.resolve_with(4, 64, 8, || {
            runs.fetch_add(1, Ordering::Relaxed);
            XnorImpl::Scalar
        });
        assert_eq!(again, XnorImpl::Wide);
        assert_eq!(runs.load(Ordering::Relaxed), 1);
        // A different shape benches once more.
        cache.resolve_with(5, 64, 8, || {
            runs.fetch_add(1, Ordering::Relaxed);
            XnorImpl::Simd
        });
        assert_eq!(runs.load(Ordering::Relaxed), 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn round_trips_through_the_sidecar_file() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let cache = CalibCache::open(Some(path.clone()));
        cache.resolve_with(64, 288, 1024, || XnorImpl::Threaded(8));
        cache.resolve_with(3, 33, 7, || XnorImpl::Blocked2x4);

        // A fresh instance over the same file: warm, zero benches.
        let warm = CalibCache::open(Some(path.clone()));
        assert_eq!(warm.len(), 2);
        let hit = warm.resolve_with(64, 288, 1024, || {
            panic!("warm cache must not bench")
        });
        assert_eq!(hit, XnorImpl::Threaded(8));
        let hit = warm
            .resolve_with(3, 33, 7, || panic!("warm cache must not bench"));
        assert_eq!(hit, XnorImpl::Blocked2x4);

        // The file is the documented line format.
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("# bitkernel calib v1\n"), "{text}");
        assert!(text.contains("|64x288x1024|threaded8"), "{text}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn foreign_fingerprints_and_junk_are_skipped() {
        let path = tmp("foreign");
        std::fs::write(
            &path,
            format!(
                "# bitkernel calib v1\n\
                 otherarch|sse2|t2|{}|4x64x8|blocked\n\
                 {}|{}|4x64x8|no-such-impl\n\
                 {}|{}|4x64x8|auto\n\
                 {}|{}|4x64|blocked\n\
                 not a cache line\n",
                impl_set(),
                cpu_fingerprint(),
                impl_set(),
                cpu_fingerprint(),
                impl_set(),
                cpu_fingerprint(),
                impl_set(),
            ),
        )
        .unwrap();
        let cache = CalibCache::open(Some(path.clone()));
        assert_eq!(cache.len(), 0, "every line should have been skipped");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn version_mismatch_ignores_the_whole_file() {
        let path = tmp("version");
        std::fs::write(
            &path,
            format!(
                "# bitkernel calib v0\n{}|{}|4x64x8|blocked\n",
                cpu_fingerprint(),
                impl_set()
            ),
        )
        .unwrap();
        let cache = CalibCache::open(Some(path.clone()));
        assert_eq!(cache.len(), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn invalidate_clears_memory_and_file() {
        let path = tmp("invalidate");
        let _ = std::fs::remove_file(&path);
        let cache = CalibCache::open(Some(path.clone()));
        cache.resolve_with(4, 64, 8, || XnorImpl::Wide);
        assert!(path.exists());
        cache.invalidate().unwrap();
        assert_eq!(cache.len(), 0);
        assert!(!path.exists());
        // Invalidating an already-clean cache is not an error.
        cache.invalidate().unwrap();
        // And the next resolve benches again, then persists again.
        let runs = AtomicUsize::new(0);
        cache.resolve_with(4, 64, 8, || {
            runs.fetch_add(1, Ordering::Relaxed);
            XnorImpl::Simd
        });
        assert_eq!(runs.load(Ordering::Relaxed), 1);
        assert!(path.exists());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn real_calibrate_lands_in_the_cache() {
        // End-to-end with the actual microbench (small shape: fast).
        let cache = CalibCache::open(None);
        let before = calibrations_total();
        let imp = cache.resolve(4, 32, 4);
        assert!(
            XnorImpl::ALL_SINGLE.contains(&imp)
                || matches!(imp, XnorImpl::Threaded(_)),
            "{imp:?}"
        );
        assert_eq!(calibrations_total(), before + 1);
        assert_eq!(cache.resolve(4, 32, 4), imp);
        assert_eq!(calibrations_total(), before + 1,
                   "second resolve must not re-bench");
    }

    #[test]
    fn fingerprint_shapes_are_stable() {
        let fp = cpu_fingerprint();
        assert_eq!(fp.matches('|').count(), 2, "{fp}");
        assert!(impl_set().contains("avx512"), "{}", impl_set());
        assert!(render_metrics()
                    .contains("bitkernel_calibrations_total"));
    }
}
