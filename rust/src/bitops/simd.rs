//! SIMD xnor-popcount kernels + vectorized sign packing.
//!
//! The paper's throughput claim lives or dies in this inner loop, so it
//! exists at four width tiers with a fixed runtime fallback chain:
//!
//! 1. **AVX-512** (`x86_64`, detected via `is_x86_feature_detected!`):
//!    xnor over 512-bit lanes (`vpxorq`) with the popcount done by the
//!    `VPOPCNTDQ` instruction (`_mm512_popcnt_epi64` — 16 packed words
//!    per step, one µop per popcount) when the CPU has it, else by a
//!    512-bit nibble-LUT `_mm512_shuffle_epi8` + `_mm512_sad_epu8`
//!    variant on AVX512BW-only parts.  Sign packing writes compare
//!    results straight out of mask registers
//!    (`_mm512_cmp_ps_mask(GE_OQ)` — the `vpmov*2m`/`kmov` family
//!    instead of a movemask round trip).
//! 2. **AVX2**: xnor over 256-bit lanes, popcount via the nibble-LUT
//!    `_mm256_shuffle_epi8` trick reduced with `_mm256_sad_epu8`
//!    (the Harley–Seal byte-count family — 8 packed words per step),
//!    and sign packing via `_mm256_cmp_ps(GE_OQ)` + `movemask`.
//! 3. **Portable wide** (any arch): `[u64; 4]`-at-a-time xnor+popcount
//!    with independent accumulators, compiling to hardware `popcnt` /
//!    `cnt` wherever the target has it.
//! 4. The scalar u32/u64 kernels in [`super::xnor`] remain as the
//!    bit-exactness oracles.
//!
//! Every tier computes the identical integer result (popcounts are
//! order-free), and the packing tiers perform the identical f32
//! compare (`v >= 0.0`, or `a*v + b >= 0.0` for the folded-BN path) —
//! `-0.0` and `NaN` behave exactly like the scalar loop, pinned by the
//! differential tests below and in `tests/prop_bitops.rs`.
//!
//! The gemm entry point here is the *tile* kernel: it fills
//! `out[i*n + j]` for a rectangular `[i_lo, i_hi) x [j_lo, j_hi)`
//! sub-block through a raw pointer, so the 2-D tiled multi-threaded
//! driver in [`super::xnor`] can hand disjoint tiles of one output
//! buffer to different workers without aliasing `&mut` slices.

use crate::tensor::PackedMatrix;

use super::xnor::finish;

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// Does this CPU have the AVX2 tier?  (Cached by std's feature
/// detection; cheap enough to call per gemm.)
#[inline]
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Does this CPU have the AVX-512 `VPOPCNTDQ` tier (512-bit xnor with
/// single-instruction 64-bit-lane popcounts)?
#[inline]
pub fn avx512_vpopcnt_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx512f")
            && is_x86_feature_detected!("avx512vpopcntdq")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Does this CPU have the AVX512BW tier (512-bit xnor with the
/// nibble-LUT/`sad_epu8` popcount — the fallback for AVX-512 parts
/// without `VPOPCNTDQ`)?
#[inline]
pub fn avx512bw_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx512f")
            && is_x86_feature_detected!("avx512bw")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Does this CPU run the 512-bit gemm tier at all (either popcount
/// flavor)?  Gates the `XnorImpl::Avx512` arm in `Auto` resolution and
/// calibration.
#[inline]
pub fn avx512_available() -> bool {
    avx512_vpopcnt_available() || avx512bw_available()
}

/// AVX512F alone is enough for the mask-register sign packing (the
/// gemm tiers additionally want BW or VPOPCNTDQ).
#[inline]
pub fn avx512f_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx512f")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Human label for the widest available tier (bench/profile reports).
pub fn simd_tier() -> &'static str {
    if avx512_vpopcnt_available() {
        "avx512-vpopcntdq"
    } else if avx512bw_available() {
        "avx512bw"
    } else if avx2_available() {
        "avx2"
    } else {
        "wide64x4"
    }
}

/// Two adjacent packed u32 words as one u64 (little-endian word order,
/// matching the bit convention: word w holds logical bits w*32..).
#[inline(always)]
fn u64_at(s: &[u32], i: usize) -> u64 {
    (s[i] as u64) | ((s[i + 1] as u64) << 32)
}

/// Popcount of the xnor of two packed rows, `[u64; 4]` per step with
/// independent accumulators (the portable wide tier).
#[inline]
pub(crate) fn popc_xnor_wide(a: &[u32], b: &[u32]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let n8 = a.len() & !7;
    let (mut c0, mut c1, mut c2, mut c3) = (0u32, 0u32, 0u32, 0u32);
    let mut i = 0;
    while i < n8 {
        c0 += (!(u64_at(a, i) ^ u64_at(b, i))).count_ones();
        c1 += (!(u64_at(a, i + 2) ^ u64_at(b, i + 2))).count_ones();
        c2 += (!(u64_at(a, i + 4) ^ u64_at(b, i + 4))).count_ones();
        c3 += (!(u64_at(a, i + 6) ^ u64_at(b, i + 6))).count_ones();
        i += 8;
    }
    let mut acc = (c0 + c1) + (c2 + c3);
    while i + 2 <= a.len() {
        acc += (!(u64_at(a, i) ^ u64_at(b, i))).count_ones();
        i += 2;
    }
    if i < a.len() {
        acc += (!(a[i] ^ b[i])).count_ones();
    }
    acc
}

/// Portable wide gemm tile: `out[i*n + j] = <w_i, x_j>` for the block
/// `[i_lo, i_hi) x [j_lo, j_hi)`.  1x4 column blocking over the
/// `[u64; 4]` reduction, so each loaded w quad is reused 4 times.
///
/// # Safety
/// `out` must be valid for writes at every `i*n + j` in the block, and
/// concurrent callers must use disjoint blocks.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn gemm_tile_wide(
    w: &PackedMatrix,
    x: &PackedMatrix,
    out: *mut i32,
    n: usize,
    i_lo: usize,
    i_hi: usize,
    j_lo: usize,
    j_hi: usize,
) {
    let (kw, pad) = (w.kw, w.pad_bits());
    let kw8 = kw & !7;
    for i in i_lo..i_hi {
        let wrow = w.row(i);
        let mut j = j_lo;
        while j + 4 <= j_hi {
            let rows =
                [x.row(j), x.row(j + 1), x.row(j + 2), x.row(j + 3)];
            let mut acc = [0u32; 4];
            let mut wi = 0;
            while wi < kw8 {
                let w0 = u64_at(wrow, wi);
                let w1 = u64_at(wrow, wi + 2);
                let w2 = u64_at(wrow, wi + 4);
                let w3 = u64_at(wrow, wi + 6);
                for (c, xr) in rows.iter().enumerate() {
                    acc[c] += (!(w0 ^ u64_at(xr, wi))).count_ones()
                        + (!(w1 ^ u64_at(xr, wi + 2))).count_ones()
                        + (!(w2 ^ u64_at(xr, wi + 4))).count_ones()
                        + (!(w3 ^ u64_at(xr, wi + 6))).count_ones();
                }
                wi += 8;
            }
            while wi < kw {
                let ww = wrow[wi];
                for (c, xr) in rows.iter().enumerate() {
                    acc[c] += (!(ww ^ xr[wi])).count_ones();
                }
                wi += 1;
            }
            for (c, &a) in acc.iter().enumerate() {
                *out.add(i * n + j + c) = finish(a, kw, pad);
            }
            j += 4;
        }
        while j < j_hi {
            *out.add(i * n + j) =
                finish(popc_xnor_wide(wrow, x.row(j)), kw, pad);
            j += 1;
        }
    }
}

/// Per-64-bit-lane popcount of a 256-bit vector: nibble LUT via
/// `shuffle_epi8`, bytes reduced with `sad_epu8` (each u64 lane holds
/// the popcount of its 8 bytes).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn popcount256(v: __m256i) -> __m256i {
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    );
    let low = _mm256_set1_epi8(0x0f);
    let lo = _mm256_and_si256(v, low);
    let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), low);
    let cnt = _mm256_add_epi8(
        _mm256_shuffle_epi8(lut, lo),
        _mm256_shuffle_epi8(lut, hi),
    );
    _mm256_sad_epu8(cnt, _mm256_setzero_si256())
}

/// Sum of the four u64 lanes of an accumulator vector.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn hsum_epi64(v: __m256i) -> u64 {
    let mut lanes = [0u64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
    lanes[0] + lanes[1] + lanes[2] + lanes[3]
}

/// AVX2 gemm tile (same contract as [`gemm_tile_wide`]): 8 packed words
/// per 256-bit step, 1x4 column blocking, vectorized popcount.
///
/// # Safety
/// Caller must have verified `avx2_available()`; `out` aliasing rules as
/// in [`gemm_tile_wide`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn gemm_tile_avx2(
    w: &PackedMatrix,
    x: &PackedMatrix,
    out: *mut i32,
    n: usize,
    i_lo: usize,
    i_hi: usize,
    j_lo: usize,
    j_hi: usize,
) {
    let (kw, pad) = (w.kw, w.pad_bits());
    let kw8 = kw & !7;
    let ones = _mm256_set1_epi64x(-1);
    for i in i_lo..i_hi {
        let wrow = w.row(i);
        let mut j = j_lo;
        while j + 4 <= j_hi {
            let rows =
                [x.row(j), x.row(j + 1), x.row(j + 2), x.row(j + 3)];
            let mut vacc = [_mm256_setzero_si256(); 4];
            let mut wi = 0;
            while wi < kw8 {
                let wv = _mm256_loadu_si256(
                    wrow.as_ptr().add(wi) as *const __m256i
                );
                for (c, xr) in rows.iter().enumerate() {
                    let xv = _mm256_loadu_si256(
                        xr.as_ptr().add(wi) as *const __m256i
                    );
                    // xnor = NOT (w XOR x) = (w XOR x) XOR ones
                    let xn = _mm256_xor_si256(_mm256_xor_si256(wv, xv),
                                              ones);
                    vacc[c] = _mm256_add_epi64(vacc[c], popcount256(xn));
                }
                wi += 8;
            }
            let mut acc = [
                hsum_epi64(vacc[0]) as u32,
                hsum_epi64(vacc[1]) as u32,
                hsum_epi64(vacc[2]) as u32,
                hsum_epi64(vacc[3]) as u32,
            ];
            while wi < kw {
                let ww = wrow[wi];
                for (c, xr) in rows.iter().enumerate() {
                    acc[c] += (!(ww ^ xr[wi])).count_ones();
                }
                wi += 1;
            }
            for (c, &a) in acc.iter().enumerate() {
                *out.add(i * n + j + c) = finish(a, kw, pad);
            }
            j += 4;
        }
        while j < j_hi {
            let xr = x.row(j);
            let mut vacc = _mm256_setzero_si256();
            let mut wi = 0;
            while wi < kw8 {
                let wv = _mm256_loadu_si256(
                    wrow.as_ptr().add(wi) as *const __m256i
                );
                let xv = _mm256_loadu_si256(
                    xr.as_ptr().add(wi) as *const __m256i
                );
                let xn =
                    _mm256_xor_si256(_mm256_xor_si256(wv, xv), ones);
                vacc = _mm256_add_epi64(vacc, popcount256(xn));
                wi += 8;
            }
            let mut acc = hsum_epi64(vacc) as u32;
            while wi < kw {
                acc += (!(wrow[wi] ^ xr[wi])).count_ones();
                wi += 1;
            }
            *out.add(i * n + j) = finish(acc, kw, pad);
            j += 1;
        }
    }
}

/// Per-64-bit-lane popcount of a 512-bit vector on AVX512BW-only
/// parts: the same nibble-LUT + `sad_epu8` trick as [`popcount256`],
/// twice as wide (16 packed words per step).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
#[inline]
unsafe fn popcount512(v: __m512i) -> __m512i {
    let lut = _mm512_broadcast_i32x4(_mm_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    ));
    let low = _mm512_set1_epi8(0x0f);
    let lo = _mm512_and_si512(v, low);
    let hi = _mm512_and_si512(_mm512_srli_epi16::<4>(v), low);
    let cnt = _mm512_add_epi8(
        _mm512_shuffle_epi8(lut, lo),
        _mm512_shuffle_epi8(lut, hi),
    );
    _mm512_sad_epu8(cnt, _mm512_setzero_si512())
}

/// `VPOPCNTDQ` 512-bit gemm tile: 16 packed words per step, xnor via
/// double-`vpxorq`, per-lane popcount in ONE instruction
/// (`_mm512_popcnt_epi64`), 1x4 column blocking, word tails scalar —
/// same contract as [`gemm_tile_wide`].
///
/// # Safety
/// Caller must have verified [`avx512_vpopcnt_available`]; `out`
/// aliasing rules as in [`gemm_tile_wide`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512vpopcntdq")]
#[allow(clippy::too_many_arguments)]
unsafe fn gemm_tile_avx512vp(
    w: &PackedMatrix,
    x: &PackedMatrix,
    out: *mut i32,
    n: usize,
    i_lo: usize,
    i_hi: usize,
    j_lo: usize,
    j_hi: usize,
) {
    let (kw, pad) = (w.kw, w.pad_bits());
    let kw16 = kw & !15;
    let ones = _mm512_set1_epi64(-1);
    for i in i_lo..i_hi {
        let wrow = w.row(i);
        let mut j = j_lo;
        while j + 4 <= j_hi {
            let rows =
                [x.row(j), x.row(j + 1), x.row(j + 2), x.row(j + 3)];
            let mut vacc = [_mm512_setzero_si512(); 4];
            let mut wi = 0;
            while wi < kw16 {
                let wv =
                    _mm512_loadu_si512(wrow.as_ptr().add(wi) as *const _);
                for (c, xr) in rows.iter().enumerate() {
                    let xv = _mm512_loadu_si512(
                        xr.as_ptr().add(wi) as *const _
                    );
                    // xnor = NOT (w XOR x) = (w XOR x) XOR ones
                    let xn = _mm512_xor_si512(_mm512_xor_si512(wv, xv),
                                              ones);
                    vacc[c] = _mm512_add_epi64(vacc[c],
                                               _mm512_popcnt_epi64(xn));
                }
                wi += 16;
            }
            let mut acc = [
                _mm512_reduce_add_epi64(vacc[0]) as u32,
                _mm512_reduce_add_epi64(vacc[1]) as u32,
                _mm512_reduce_add_epi64(vacc[2]) as u32,
                _mm512_reduce_add_epi64(vacc[3]) as u32,
            ];
            while wi < kw {
                let ww = wrow[wi];
                for (c, xr) in rows.iter().enumerate() {
                    acc[c] += (!(ww ^ xr[wi])).count_ones();
                }
                wi += 1;
            }
            for (c, &a) in acc.iter().enumerate() {
                *out.add(i * n + j + c) = finish(a, kw, pad);
            }
            j += 4;
        }
        while j < j_hi {
            let xr = x.row(j);
            let mut vacc = _mm512_setzero_si512();
            let mut wi = 0;
            while wi < kw16 {
                let wv =
                    _mm512_loadu_si512(wrow.as_ptr().add(wi) as *const _);
                let xv =
                    _mm512_loadu_si512(xr.as_ptr().add(wi) as *const _);
                let xn =
                    _mm512_xor_si512(_mm512_xor_si512(wv, xv), ones);
                vacc = _mm512_add_epi64(vacc, _mm512_popcnt_epi64(xn));
                wi += 16;
            }
            let mut acc = _mm512_reduce_add_epi64(vacc) as u32;
            while wi < kw {
                acc += (!(wrow[wi] ^ xr[wi])).count_ones();
                wi += 1;
            }
            *out.add(i * n + j) = finish(acc, kw, pad);
            j += 1;
        }
    }
}

/// AVX512BW 512-bit gemm tile for parts without `VPOPCNTDQ`: identical
/// structure to [`gemm_tile_avx512vp`] with the nibble-LUT
/// [`popcount512`] in place of the single instruction, compiled WITHOUT
/// the `avx512vpopcntdq` feature so no such instruction can be emitted.
///
/// # Safety
/// Caller must have verified [`avx512bw_available`]; `out` aliasing
/// rules as in [`gemm_tile_wide`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
#[allow(clippy::too_many_arguments)]
unsafe fn gemm_tile_avx512bw(
    w: &PackedMatrix,
    x: &PackedMatrix,
    out: *mut i32,
    n: usize,
    i_lo: usize,
    i_hi: usize,
    j_lo: usize,
    j_hi: usize,
) {
    let (kw, pad) = (w.kw, w.pad_bits());
    let kw16 = kw & !15;
    let ones = _mm512_set1_epi64(-1);
    for i in i_lo..i_hi {
        let wrow = w.row(i);
        let mut j = j_lo;
        while j + 4 <= j_hi {
            let rows =
                [x.row(j), x.row(j + 1), x.row(j + 2), x.row(j + 3)];
            let mut vacc = [_mm512_setzero_si512(); 4];
            let mut wi = 0;
            while wi < kw16 {
                let wv =
                    _mm512_loadu_si512(wrow.as_ptr().add(wi) as *const _);
                for (c, xr) in rows.iter().enumerate() {
                    let xv = _mm512_loadu_si512(
                        xr.as_ptr().add(wi) as *const _
                    );
                    let xn = _mm512_xor_si512(_mm512_xor_si512(wv, xv),
                                              ones);
                    vacc[c] =
                        _mm512_add_epi64(vacc[c], popcount512(xn));
                }
                wi += 16;
            }
            let mut acc = [
                _mm512_reduce_add_epi64(vacc[0]) as u32,
                _mm512_reduce_add_epi64(vacc[1]) as u32,
                _mm512_reduce_add_epi64(vacc[2]) as u32,
                _mm512_reduce_add_epi64(vacc[3]) as u32,
            ];
            while wi < kw {
                let ww = wrow[wi];
                for (c, xr) in rows.iter().enumerate() {
                    acc[c] += (!(ww ^ xr[wi])).count_ones();
                }
                wi += 1;
            }
            for (c, &a) in acc.iter().enumerate() {
                *out.add(i * n + j + c) = finish(a, kw, pad);
            }
            j += 4;
        }
        while j < j_hi {
            let xr = x.row(j);
            let mut vacc = _mm512_setzero_si512();
            let mut wi = 0;
            while wi < kw16 {
                let wv =
                    _mm512_loadu_si512(wrow.as_ptr().add(wi) as *const _);
                let xv =
                    _mm512_loadu_si512(xr.as_ptr().add(wi) as *const _);
                let xn =
                    _mm512_xor_si512(_mm512_xor_si512(wv, xv), ones);
                vacc = _mm512_add_epi64(vacc, popcount512(xn));
                wi += 16;
            }
            let mut acc = _mm512_reduce_add_epi64(vacc) as u32;
            while wi < kw {
                acc += (!(wrow[wi] ^ xr[wi])).count_ones();
                wi += 1;
            }
            *out.add(i * n + j) = finish(acc, kw, pad);
            j += 1;
        }
    }
}

/// 512-bit gemm tile with runtime popcount-flavor dispatch, falling
/// through to AVX2 then the portable wide tier on CPUs without
/// AVX-512 — the `XnorImpl::Avx512` arm.  Same contract/safety as
/// [`gemm_tile_wide`].
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn gemm_tile_avx512(
    w: &PackedMatrix,
    x: &PackedMatrix,
    out: *mut i32,
    n: usize,
    i_lo: usize,
    i_hi: usize,
    j_lo: usize,
    j_hi: usize,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if avx512_vpopcnt_available() {
            return gemm_tile_avx512vp(w, x, out, n, i_lo, i_hi, j_lo,
                                      j_hi);
        }
        if avx512bw_available() {
            return gemm_tile_avx512bw(w, x, out, n, i_lo, i_hi, j_lo,
                                      j_hi);
        }
    }
    gemm_tile_avx2_or_wide(w, x, out, n, i_lo, i_hi, j_lo, j_hi)
}

/// The 256-bit tier pinned: AVX2 when the CPU has it, else the
/// portable wide tier — the `XnorImpl::Simd` arm (kept at 256 bits so
/// benches can compare it against [`gemm_tile_avx512`] on the same
/// machine).  Same contract/safety as [`gemm_tile_wide`].
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn gemm_tile_avx2_or_wide(
    w: &PackedMatrix,
    x: &PackedMatrix,
    out: *mut i32,
    n: usize,
    i_lo: usize,
    i_hi: usize,
    j_lo: usize,
    j_hi: usize,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_available() {
            return gemm_tile_avx2(w, x, out, n, i_lo, i_hi, j_lo, j_hi);
        }
    }
    gemm_tile_wide(w, x, out, n, i_lo, i_hi, j_lo, j_hi)
}

/// Widest-available gemm tile: the AVX-512 tiers when the CPU has
/// them, else AVX2, else the portable wide tier.  This is what
/// `Threaded` hands its 2-D tiles to.  Same contract/safety as
/// [`gemm_tile_wide`].
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn gemm_tile_best(
    w: &PackedMatrix,
    x: &PackedMatrix,
    out: *mut i32,
    n: usize,
    i_lo: usize,
    i_hi: usize,
    j_lo: usize,
    j_hi: usize,
) {
    #[cfg(target_arch = "x86_64")]
    {
        if avx512_vpopcnt_available() {
            return gemm_tile_avx512vp(w, x, out, n, i_lo, i_hi, j_lo,
                                      j_hi);
        }
        if avx512bw_available() {
            return gemm_tile_avx512bw(w, x, out, n, i_lo, i_hi, j_lo,
                                      j_hi);
        }
        if avx2_available() {
            return gemm_tile_avx2(w, x, out, n, i_lo, i_hi, j_lo, j_hi);
        }
    }
    gemm_tile_wide(w, x, out, n, i_lo, i_hi, j_lo, j_hi)
}

// ---------------------------------------------------------------------------
// Sign packing: f32 runs -> packed sign words
// ---------------------------------------------------------------------------

#[inline]
fn pack_words_scalar(vals: &[f32], out: &mut [u32]) {
    for (word, chunk) in out.iter_mut().zip(vals.chunks_exact(32)) {
        let mut acc = 0u32;
        for (i, &v) in chunk.iter().enumerate() {
            acc |= u32::from(v >= 0.0) << i;
        }
        *word = acc;
    }
}

#[inline]
fn pack_words_bn_scalar(vals: &[f32], a: f32, b: f32, out: &mut [u32]) {
    for (word, chunk) in out.iter_mut().zip(vals.chunks_exact(32)) {
        let mut acc = 0u32;
        for (i, &v) in chunk.iter().enumerate() {
            acc |= u32::from(a * v + b >= 0.0) << i;
        }
        *word = acc;
    }
}

/// One packed word from 32 floats: four 8-lane `v >= 0` compares +
/// movemask.  `GE_OQ` matches the scalar `>=` exactly (`-0.0` -> true,
/// `NaN` -> false).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn pack_words_avx2(vals: &[f32], out: &mut [u32]) {
    let zero = _mm256_setzero_ps();
    for (wi, word) in out.iter_mut().enumerate() {
        let base = vals.as_ptr().add(wi * 32);
        let mut acc = 0u32;
        for g in 0..4 {
            let v = _mm256_loadu_ps(base.add(g * 8));
            let m = _mm256_cmp_ps::<_CMP_GE_OQ>(v, zero);
            acc |= ((_mm256_movemask_ps(m) as u32) & 0xff) << (g * 8);
        }
        *word = acc;
    }
}

/// BN-folded variant: packs `a*v + b >= 0`.  Mul-then-add (no FMA), so
/// the rounding is bit-identical to the scalar expression.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn pack_words_bn_avx2(vals: &[f32], a: f32, b: f32,
                             out: &mut [u32]) {
    let zero = _mm256_setzero_ps();
    let av = _mm256_set1_ps(a);
    let bv = _mm256_set1_ps(b);
    for (wi, word) in out.iter_mut().enumerate() {
        let base = vals.as_ptr().add(wi * 32);
        let mut acc = 0u32;
        for g in 0..4 {
            let v = _mm256_loadu_ps(base.add(g * 8));
            let t = _mm256_add_ps(_mm256_mul_ps(av, v), bv);
            let m = _mm256_cmp_ps::<_CMP_GE_OQ>(t, zero);
            acc |= ((_mm256_movemask_ps(m) as u32) & 0xff) << (g * 8);
        }
        *word = acc;
    }
}

/// AVX-512 packing: one `v >= 0` compare per 16 lanes lands directly
/// in a mask register (`_mm512_cmp_ps_mask`, the `vpmov*2m`/`kmov`
/// family — no movemask round trip), two masks per packed word.
/// `GE_OQ` matches the scalar `>=` exactly (`-0.0` -> true, `NaN` ->
/// false).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn pack_words_avx512(vals: &[f32], out: &mut [u32]) {
    let zero = _mm512_setzero_ps();
    for (wi, word) in out.iter_mut().enumerate() {
        let base = vals.as_ptr().add(wi * 32);
        let lo = _mm512_cmp_ps_mask::<_CMP_GE_OQ>(
            _mm512_loadu_ps(base), zero,
        ) as u32;
        let hi = _mm512_cmp_ps_mask::<_CMP_GE_OQ>(
            _mm512_loadu_ps(base.add(16)), zero,
        ) as u32;
        *word = lo | (hi << 16);
    }
}

/// BN-folded AVX-512 packing: `a*v + b >= 0` into mask registers.
/// Mul-then-add (explicit intrinsics, no FMA contraction), so the
/// rounding is bit-identical to the scalar expression.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn pack_words_bn_avx512(vals: &[f32], a: f32, b: f32,
                               out: &mut [u32]) {
    let zero = _mm512_setzero_ps();
    let av = _mm512_set1_ps(a);
    let bv = _mm512_set1_ps(b);
    for (wi, word) in out.iter_mut().enumerate() {
        let base = vals.as_ptr().add(wi * 32);
        let mut acc = 0u32;
        for g in 0..2 {
            let v = _mm512_loadu_ps(base.add(g * 16));
            let t = _mm512_add_ps(_mm512_mul_ps(av, v), bv);
            let m = _mm512_cmp_ps_mask::<_CMP_GE_OQ>(t, zero) as u32;
            acc |= m << (g * 16);
        }
        *word = acc;
    }
}

/// Pack full words of sign bits: `vals.len() == out.len() * 32`
/// (callers handle ragged tails).  Bit 1 <=> `v >= 0.0`.
#[inline]
pub(crate) fn pack_words(vals: &[f32], out: &mut [u32]) {
    debug_assert_eq!(vals.len(), out.len() * 32);
    #[cfg(target_arch = "x86_64")]
    {
        if avx512f_available() {
            unsafe { pack_words_avx512(vals, out) };
            return;
        }
        if avx2_available() {
            unsafe { pack_words_avx2(vals, out) };
            return;
        }
    }
    pack_words_scalar(vals, out);
}

/// [`pack_words`] with the previous layer's per-channel BN affine folded
/// into the sign: bit 1 <=> `a*v + b >= 0.0`.
#[inline]
pub(crate) fn pack_words_bn(vals: &[f32], a: f32, b: f32,
                            out: &mut [u32]) {
    debug_assert_eq!(vals.len(), out.len() * 32);
    #[cfg(target_arch = "x86_64")]
    {
        if avx512f_available() {
            unsafe { pack_words_bn_avx512(vals, a, b, out) };
            return;
        }
        if avx2_available() {
            unsafe { pack_words_bn_avx2(vals, a, b, out) };
            return;
        }
    }
    pack_words_bn_scalar(vals, a, b, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::pack_rows;
    use crate::utils::Rng;

    fn popc_xnor_u32_ref(a: &[u32], b: &[u32]) -> u32 {
        a.iter().zip(b).map(|(&x, &y)| (!(x ^ y)).count_ones()).sum()
    }

    #[test]
    fn wide_popcount_matches_u32_reference() {
        let mut rng = Rng::new(91);
        for words in [1usize, 2, 7, 8, 9, 15, 16, 33] {
            let a: Vec<u32> = (0..words).map(|_| rng.next_u32()).collect();
            let b: Vec<u32> = (0..words).map(|_| rng.next_u32()).collect();
            assert_eq!(popc_xnor_wide(&a, &b), popc_xnor_u32_ref(&a, &b),
                       "words={words}");
        }
    }

    fn tile_vs_scalar(d: usize, k: usize, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let w = pack_rows(&rng.sign_vec(d * k), d, k);
        let x = pack_rows(&rng.sign_vec(n * k), n, k);
        let mut want = vec![0i32; d * n];
        crate::bitops::xnor_gemm(&w, &x, &mut want,
                                 crate::bitops::XnorImpl::Scalar);

        // full-range tile, every dispatch chain (each resolves to the
        // widest tier this host actually has, so the AVX-512 kernels
        // are covered wherever the CPU supports them)
        let mut wide = vec![0i32; d * n];
        unsafe { gemm_tile_wide(&w, &x, wide.as_mut_ptr(), n, 0, d, 0, n) };
        assert_eq!(wide, want, "wide d={d} k={k} n={n}");
        let mut v256 = vec![0i32; d * n];
        unsafe {
            gemm_tile_avx2_or_wide(&w, &x, v256.as_mut_ptr(), n, 0, d,
                                   0, n)
        };
        assert_eq!(v256, want, "avx2-or-wide d={d} k={k} n={n}");
        let mut v512 = vec![0i32; d * n];
        unsafe {
            gemm_tile_avx512(&w, &x, v512.as_mut_ptr(), n, 0, d, 0, n)
        };
        assert_eq!(v512, want, "avx512 d={d} k={k} n={n}");
        let mut best = vec![0i32; d * n];
        unsafe { gemm_tile_best(&w, &x, best.as_mut_ptr(), n, 0, d, 0, n) };
        assert_eq!(best, want, "best d={d} k={k} n={n}");

        // a strict sub-tile only touches its own cells
        if d >= 2 && n >= 3 {
            let mut part = vec![i32::MIN; d * n];
            unsafe {
                gemm_tile_best(&w, &x, part.as_mut_ptr(), n, 1, d, 1,
                               n - 1)
            };
            for i in 0..d {
                for j in 0..n {
                    let inside = i >= 1 && (1..n - 1).contains(&j);
                    if inside {
                        assert_eq!(part[i * n + j], want[i * n + j],
                                   "({i},{j})");
                    } else {
                        assert_eq!(part[i * n + j], i32::MIN,
                                   "({i},{j}) written outside tile");
                    }
                }
            }
        }
    }

    #[test]
    fn tiles_match_scalar_over_ragged_shapes() {
        // k=513/1023 cross the 16-word (512-bit) step boundary so the
        // AVX-512 main loops hit their scalar word tails too.
        for (d, k, n) in [(1, 1, 1), (3, 31, 5), (4, 32, 4), (5, 33, 7),
                          (2, 255, 3), (3, 257, 9), (8, 256, 8),
                          (7, 289, 6), (3, 513, 5), (2, 1023, 6)] {
            tile_vs_scalar(d, k, n, (d * 7919 + k * 31 + n) as u64);
        }
    }

    #[test]
    fn pack_words_matches_scalar_compare() {
        let mut rng = Rng::new(92);
        for words in [1usize, 2, 3, 8] {
            let mut vals = rng.normal_vec(words * 32);
            // poison with the compare edge cases
            vals[0] = 0.0;
            vals[1] = -0.0;
            vals[2] = f32::NAN;
            let mut got = vec![0u32; words];
            pack_words(&vals, &mut got);
            let mut want = vec![0u32; words];
            pack_words_scalar(&vals, &mut want);
            assert_eq!(got, want);
            // and bit 0/1 semantics: 0.0 -> 1, -0.0 -> 1, NaN -> 0
            assert_eq!(got[0] & 0b111, 0b011);

            let (a, b) = (-1.25f32, 0.375f32);
            let mut got_bn = vec![0u32; words];
            pack_words_bn(&vals, a, b, &mut got_bn);
            let mut want_bn = vec![0u32; words];
            pack_words_bn_scalar(&vals, a, b, &mut want_bn);
            assert_eq!(got_bn, want_bn);
        }
    }
}
