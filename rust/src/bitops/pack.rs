//! Encoding: float {-1,+1} (or arbitrary sign) matrices -> packed bits.
//!
//! The paper's Sec. 3.1: weights pack along rows (done once, offline);
//! activations pack the columns of the im2col matrix — which this engine
//! stores transposed ([N, K] row-major), so both cases are row packing.
//!
//! Convention (must match python/compile/kernels/ref.py and rust tests'
//! golden vectors): sign(x) = +1 iff x >= 0; encoding bit 1 <=> +1;
//! bit i of word w encodes logical element w*32 + i; padding bits are 0.

use crate::tensor::PackedMatrix;

use super::simd;

/// Streaming bit packer for one packed row: accumulates each 32-bit
/// word in a register and stores it once (a read-modify-write per bit
/// costs ~4x; §Perf optimization 2).  Callers push exactly `k` bits in
/// logical order, then `finish()`; every word of the row (including the
/// zero tail-padding bits of the last partial word) gets written.
///
/// Contiguous sign runs should go through [`BitWriter::push_signs`] /
/// [`BitWriter::push_signs_bn`]: once the run reaches a word boundary
/// they emit whole words via the SIMD pack (`bitops::simd`,
/// movemask-based on AVX2) instead of per-element shifts.
///
/// This is THE activation-side encoding loop — `nn::im2col` (fused
/// im2col+pack) and `nn::fuse` (bn_sign_pack epilogues) both build rows
/// through it, so the bit convention can never drift between them.
pub(crate) struct BitWriter<'a> {
    row: &'a mut [u32],
    word: u32,
    bits: u32,
    widx: usize,
}

impl<'a> BitWriter<'a> {
    #[inline]
    pub(crate) fn new(row: &'a mut [u32]) -> Self {
        Self { row, word: 0, bits: 0, widx: 0 }
    }

    #[inline]
    pub(crate) fn push(&mut self, bit: u32) {
        self.word |= bit << self.bits;
        self.bits += 1;
        if self.bits == 32 {
            self.row[self.widx] = self.word;
            self.widx += 1;
            self.word = 0;
            self.bits = 0;
        }
    }

    /// Push one sign bit per element of `vals` (bit 1 <=> `v >= 0.0`),
    /// vectorizing the word-aligned middle of the run.
    #[inline]
    pub(crate) fn push_signs(&mut self, vals: &[f32]) {
        let mut rest = vals;
        // Head: finish the current partial word bit by bit.
        while self.bits != 0 && !rest.is_empty() {
            self.push(u32::from(rest[0] >= 0.0));
            rest = &rest[1..];
        }
        // Aligned middle: whole words through the SIMD pack.
        let words = rest.len() / 32;
        if words > 0 {
            simd::pack_words(&rest[..words * 32],
                             &mut self.row[self.widx..self.widx + words]);
            self.widx += words;
            rest = &rest[words * 32..];
        }
        // Tail.
        for &v in rest {
            self.push(u32::from(v >= 0.0));
        }
    }

    /// [`BitWriter::push_signs`] with a folded affine: bit 1 <=>
    /// `a*v + b >= 0.0` (bit-identical to pushing the materialized
    /// affine: same mul-then-add per element).
    #[inline]
    pub(crate) fn push_signs_bn(&mut self, vals: &[f32], a: f32, b: f32) {
        let mut rest = vals;
        while self.bits != 0 && !rest.is_empty() {
            self.push(u32::from(a * rest[0] + b >= 0.0));
            rest = &rest[1..];
        }
        let words = rest.len() / 32;
        if words > 0 {
            simd::pack_words_bn(&rest[..words * 32], a, b,
                                &mut self.row[self.widx..self.widx + words]);
            self.widx += words;
            rest = &rest[words * 32..];
        }
        for &v in rest {
            self.push(u32::from(a * v + b >= 0.0));
        }
    }

    #[inline]
    pub(crate) fn finish(self) {
        if self.bits > 0 {
            self.row[self.widx] = self.word;
        }
    }
}

/// Pack one logical row (`row.len() == k`) into `out` (`ceil(k/32)` words).
///
/// Full words go through the SIMD pack (movemask-based on AVX2 — no
/// per-element branches or shifts); only the ragged tail word is built
/// bit by bit.  The compare is `v >= 0.0` (incl. `-0.0` per IEEE).
#[inline]
pub fn pack_slice(row: &[f32], out: &mut [u32]) {
    debug_assert_eq!(out.len(), row.len().div_ceil(32));
    let full = row.len() / 32;
    simd::pack_words(&row[..full * 32], &mut out[..full]);
    // Tail (the word's padding bits stay zero).
    let tail_start = full * 32;
    if tail_start < row.len() {
        let mut word = 0u32;
        for (i, &v) in row[tail_start..].iter().enumerate() {
            word |= u32::from(v >= 0.0) << i;
        }
        out[full] = word;
    }
}

/// Pack a row-major [rows, k] float matrix.
pub fn pack_rows(mat: &[f32], rows: usize, k: usize) -> PackedMatrix {
    assert_eq!(mat.len(), rows * k, "matrix len vs rows*k");
    let mut p = PackedMatrix::zeros(rows, k);
    let kw = p.kw;
    for r in 0..rows {
        pack_slice(&mat[r * k..(r + 1) * k], &mut p.data[r * kw..(r + 1) * kw]);
    }
    p
}

/// Pack into an existing, correctly-sized PackedMatrix (no allocation —
/// the per-request hot path reuses buffers).
pub fn pack_rows_from(mat: &[f32], p: &mut PackedMatrix) {
    assert_eq!(mat.len(), p.rows * p.k);
    let kw = p.kw;
    let k = p.k;
    for r in 0..p.rows {
        pack_slice(&mat[r * k..(r + 1) * k], &mut p.data[r * kw..(r + 1) * kw]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_order_little_endian() {
        // element 0 -> bit 0 of word 0; element 33 -> bit 1 of word 1.
        let mut row = vec![-1.0f32; 64];
        row[0] = 1.0;
        row[33] = 1.0;
        let p = pack_rows(&row, 1, 64);
        assert_eq!(p.data, vec![1, 2]);
    }

    #[test]
    fn zero_packs_as_plus_one() {
        let p = pack_rows(&[0.0, -0.0, -1.0, 2.0], 1, 4);
        // 0.0 -> 1, -0.0 -> 1 (>= 0 in IEEE), -1 -> 0, 2 -> 1
        assert_eq!(p.data, vec![0b1011]);
    }

    #[test]
    fn padding_bits_are_zero() {
        let p = pack_rows(&[1.0; 40], 1, 40);
        assert_eq!(p.kw, 2);
        assert_eq!(p.data[0], u32::MAX);
        assert_eq!(p.data[1], 0xFF); // 8 real bits, 24 pad zeros
        assert_eq!(p.pad_bits(), 24);
    }

    #[test]
    fn roundtrip_via_get() {
        let vals: Vec<f32> = (0..70)
            .map(|i| if (i * 7) % 3 == 0 { 1.0 } else { -1.0 })
            .collect();
        let p = pack_rows(&vals, 1, 70);
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(p.get(0, i), v, "element {i}");
        }
    }

    #[test]
    fn multi_row_independent() {
        let mat = [1.0, -1.0, -1.0, 1.0];
        let p = pack_rows(&mat, 2, 2);
        assert_eq!(p.kw, 1);
        assert_eq!(p.data, vec![0b01, 0b10]);
    }

    #[test]
    fn pack_rows_from_reuses_buffer() {
        let mut p = PackedMatrix::zeros(2, 40);
        pack_rows_from(&vec![1.0; 80], &mut p);
        assert_eq!(p.data, vec![u32::MAX, 0xFF, u32::MAX, 0xFF]);
        pack_rows_from(&vec![-1.0; 80], &mut p);
        assert_eq!(p.data, vec![0, 0, 0, 0]);
    }

    #[test]
    fn push_signs_matches_per_bit_pushes() {
        use crate::utils::Rng;
        let mut rng = Rng::new(77);
        for (head, run, tail) in [(0usize, 64usize, 0usize), (3, 70, 2),
                                  (31, 33, 1), (1, 100, 0), (5, 7, 0),
                                  (0, 31, 0), (32, 32, 32)] {
            let total = head + run + tail;
            let vals = rng.normal_vec(total);
            let (a, b) = (-0.75f32, 0.125f32);
            let kw = total.div_ceil(32);

            let mut want = vec![0u32; kw];
            let mut bw = BitWriter::new(&mut want);
            for &v in &vals {
                bw.push(u32::from(v >= 0.0));
            }
            bw.finish();
            let mut got = vec![0u32; kw];
            let mut bw = BitWriter::new(&mut got);
            for &v in &vals[..head] {
                bw.push(u32::from(v >= 0.0));
            }
            bw.push_signs(&vals[head..head + run]);
            for &v in &vals[head + run..] {
                bw.push(u32::from(v >= 0.0));
            }
            bw.finish();
            assert_eq!(got, want, "plain h{head} r{run} t{tail}");

            let mut want = vec![0u32; kw];
            let mut bw = BitWriter::new(&mut want);
            for &v in &vals {
                bw.push(u32::from(a * v + b >= 0.0));
            }
            bw.finish();
            let mut got = vec![0u32; kw];
            let mut bw = BitWriter::new(&mut got);
            for &v in &vals[..head] {
                bw.push(u32::from(a * v + b >= 0.0));
            }
            bw.push_signs_bn(&vals[head..head + run], a, b);
            for &v in &vals[head + run..] {
                bw.push(u32::from(a * v + b >= 0.0));
            }
            bw.finish();
            assert_eq!(got, want, "bn h{head} r{run} t{tail}");
        }
    }

    /// Golden vector shared with python (tests/test_cross_language.py
    /// generates the same case and asserts the same words).
    #[test]
    fn golden_cross_language() {
        let vals: Vec<f32> = (0..40)
            .map(|i| (i as f32 * 0.7).sin())
            .collect();
        let p = pack_rows(&vals, 1, 40);
        let mut want0 = 0u32;
        let mut want1 = 0u32;
        for (i, &v) in vals.iter().enumerate() {
            if v >= 0.0 {
                if i < 32 {
                    want0 |= 1 << i;
                } else {
                    want1 |= 1 << (i - 32);
                }
            }
        }
        assert_eq!(p.data, vec![want0, want1]);
    }
}
