//! Encoding: float {-1,+1} (or arbitrary sign) matrices -> packed bits.
//!
//! The paper's Sec. 3.1: weights pack along rows (done once, offline);
//! activations pack the columns of the im2col matrix — which this engine
//! stores transposed ([N, K] row-major), so both cases are row packing.
//!
//! Convention (must match python/compile/kernels/ref.py and rust tests'
//! golden vectors): sign(x) = +1 iff x >= 0; encoding bit 1 <=> +1;
//! bit i of word w encodes logical element w*32 + i; padding bits are 0.

use crate::tensor::PackedMatrix;

/// Streaming bit packer for one packed row: accumulates each 32-bit
/// word in a register and stores it once (a read-modify-write per bit
/// costs ~4x; §Perf optimization 2).  Callers push exactly `k` bits in
/// logical order, then `finish()`; every word of the row (including the
/// zero tail-padding bits of the last partial word) gets written.
///
/// This is THE activation-side encoding loop — `nn::im2col` (fused
/// im2col+pack) and `nn::fuse` (bn_sign_pack epilogues) both build rows
/// through it, so the bit convention can never drift between them.
pub(crate) struct BitWriter<'a> {
    row: &'a mut [u32],
    word: u32,
    bits: u32,
    widx: usize,
}

impl<'a> BitWriter<'a> {
    #[inline]
    pub(crate) fn new(row: &'a mut [u32]) -> Self {
        Self { row, word: 0, bits: 0, widx: 0 }
    }

    #[inline]
    pub(crate) fn push(&mut self, bit: u32) {
        self.word |= bit << self.bits;
        self.bits += 1;
        if self.bits == 32 {
            self.row[self.widx] = self.word;
            self.widx += 1;
            self.word = 0;
            self.bits = 0;
        }
    }

    #[inline]
    pub(crate) fn finish(self) {
        if self.bits > 0 {
            self.row[self.widx] = self.word;
        }
    }
}

/// Pack one logical row (`row.len() == k`) into `out` (`ceil(k/32)` words).
#[inline]
pub fn pack_slice(row: &[f32], out: &mut [u32]) {
    debug_assert_eq!(out.len(), row.len().div_ceil(32));
    out.fill(0);
    // Full 32-element words: branch-free shift-accumulate.
    let full = row.len() / 32;
    for (w, chunk) in row.chunks_exact(32).enumerate().take(full) {
        let mut word = 0u32;
        for (i, &v) in chunk.iter().enumerate() {
            // f32 sign-bit trick: v >= 0.0 (incl. -0.0 per IEEE compare)
            word |= u32::from(v >= 0.0) << i;
        }
        out[w] = word;
    }
    // Tail.
    let tail_start = full * 32;
    if tail_start < row.len() {
        let mut word = 0u32;
        for (i, &v) in row[tail_start..].iter().enumerate() {
            word |= u32::from(v >= 0.0) << i;
        }
        out[full] = word;
    }
}

/// Pack a row-major [rows, k] float matrix.
pub fn pack_rows(mat: &[f32], rows: usize, k: usize) -> PackedMatrix {
    assert_eq!(mat.len(), rows * k, "matrix len vs rows*k");
    let mut p = PackedMatrix::zeros(rows, k);
    let kw = p.kw;
    for r in 0..rows {
        pack_slice(&mat[r * k..(r + 1) * k], &mut p.data[r * kw..(r + 1) * kw]);
    }
    p
}

/// Pack into an existing, correctly-sized PackedMatrix (no allocation —
/// the per-request hot path reuses buffers).
pub fn pack_rows_from(mat: &[f32], p: &mut PackedMatrix) {
    assert_eq!(mat.len(), p.rows * p.k);
    let kw = p.kw;
    let k = p.k;
    for r in 0..p.rows {
        pack_slice(&mat[r * k..(r + 1) * k], &mut p.data[r * kw..(r + 1) * kw]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_order_little_endian() {
        // element 0 -> bit 0 of word 0; element 33 -> bit 1 of word 1.
        let mut row = vec![-1.0f32; 64];
        row[0] = 1.0;
        row[33] = 1.0;
        let p = pack_rows(&row, 1, 64);
        assert_eq!(p.data, vec![1, 2]);
    }

    #[test]
    fn zero_packs_as_plus_one() {
        let p = pack_rows(&[0.0, -0.0, -1.0, 2.0], 1, 4);
        // 0.0 -> 1, -0.0 -> 1 (>= 0 in IEEE), -1 -> 0, 2 -> 1
        assert_eq!(p.data, vec![0b1011]);
    }

    #[test]
    fn padding_bits_are_zero() {
        let p = pack_rows(&[1.0; 40], 1, 40);
        assert_eq!(p.kw, 2);
        assert_eq!(p.data[0], u32::MAX);
        assert_eq!(p.data[1], 0xFF); // 8 real bits, 24 pad zeros
        assert_eq!(p.pad_bits(), 24);
    }

    #[test]
    fn roundtrip_via_get() {
        let vals: Vec<f32> = (0..70)
            .map(|i| if (i * 7) % 3 == 0 { 1.0 } else { -1.0 })
            .collect();
        let p = pack_rows(&vals, 1, 70);
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(p.get(0, i), v, "element {i}");
        }
    }

    #[test]
    fn multi_row_independent() {
        let mat = [1.0, -1.0, -1.0, 1.0];
        let p = pack_rows(&mat, 2, 2);
        assert_eq!(p.kw, 1);
        assert_eq!(p.data, vec![0b01, 0b10]);
    }

    #[test]
    fn pack_rows_from_reuses_buffer() {
        let mut p = PackedMatrix::zeros(2, 40);
        pack_rows_from(&vec![1.0; 80], &mut p);
        assert_eq!(p.data, vec![u32::MAX, 0xFF, u32::MAX, 0xFF]);
        pack_rows_from(&vec![-1.0; 80], &mut p);
        assert_eq!(p.data, vec![0, 0, 0, 0]);
    }

    /// Golden vector shared with python (tests/test_cross_language.py
    /// generates the same case and asserts the same words).
    #[test]
    fn golden_cross_language() {
        let vals: Vec<f32> = (0..40)
            .map(|i| (i as f32 * 0.7).sin())
            .collect();
        let p = pack_rows(&vals, 1, 40);
        let mut want0 = 0u32;
        let mut want1 = 0u32;
        for (i, &v) in vals.iter().enumerate() {
            if v >= 0.0 {
                if i < 32 {
                    want0 |= 1 << i;
                } else {
                    want1 |= 1 << (i - 32);
                }
            }
        }
        assert_eq!(p.data, vec![want0, want1]);
    }
}
