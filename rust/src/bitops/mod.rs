//! Bit packing + the xnor-bitcount gemm family — the paper's Sec. 3
//! kernel, natively in rust (the Table-2 "CPU" arm).
//!
//! * [`pack`] — encode float tensors into [`crate::tensor::PackedMatrix`]
//!   (bit 1 <=> value +1, little-endian within each u32 word, identical
//!   to the python ref/pallas convention — pinned by golden tests),
//! * [`xnor`] — `a[i,j] = 2*popcount(~(w ^ x)) - 32` accumulated over the
//!   packed reduction, as an implementation ladder (scalar u32, u64
//!   words, register-blocked, SIMD/wide, 2-D tiled multi-threaded, and
//!   a shape-aware `Auto`) benchmarked against each other in
//!   `benches/ablation.rs`,
//! * [`simd`] — the vectorized tiers behind the ladder: AVX-512
//!   (`vpxorq` + `VPOPCNTDQ`, with an AVX512BW nibble-LUT variant) and
//!   AVX2 xnor+popcount tiles, mask-register/movemask sign packing,
//!   and a portable `[u64; 4]`-wide fallback.

pub mod pack;
pub mod simd;
pub mod xnor;

pub use pack::{pack_rows, pack_rows_from, pack_slice};
pub use simd::{avx2_available, avx512_available, avx512_vpopcnt_available,
               avx512bw_available, avx512f_available, simd_tier};
pub use xnor::{ternary_gemm, ternary_gemm_pooled, xnor_gemm,
               xnor_gemm_pooled, XnorImpl};
