//! Bit packing + the xnor-bitcount gemm family — the paper's Sec. 3
//! kernel, natively in rust (the Table-2 "CPU" arm).
//!
//! * [`pack`] — encode float tensors into [`crate::tensor::PackedMatrix`]
//!   (bit 1 <=> value +1, little-endian within each u32 word, identical
//!   to the python ref/pallas convention — pinned by golden tests),
//! * [`xnor`] — `a[i,j] = 2*popcount(~(w ^ x)) - 32` accumulated over the
//!   packed reduction, in four implementations (scalar u32, u64 words,
//!   register-blocked, multi-threaded) benchmarked against each other in
//!   `benches/ablation.rs`.

pub mod pack;
pub mod xnor;

pub use pack::{pack_rows, pack_rows_from, pack_slice};
pub use xnor::{xnor_gemm, XnorImpl};
