//! The xnor-bitcount gemm (paper Sec. 3.2), from scalar oracle to SIMD.
//!
//! All implementations compute, for packed operands `w` ([D, k] logical)
//! and `x` ([N, k] logical — the im2col matrix transposed so its
//! reduction is contiguous):
//!
//! ```text
//!     out[i, j] = sum_over_words( 2 * popcount(~(w[i,w] ^ x[j,w])) - 32 )
//!                 - pad_bits
//! ```
//!
//! which equals the float dot product of the underlying {-1,+1} rows
//! exactly.  `popcount` compiles to the hardware `popcnt` instruction
//! (the paper uses libpopcnt / CUDA `__popc`); the SIMD tier vectorizes
//! it over 256-bit lanes (see [`super::simd`]).
//!
//! Implementations (ablated in benches/ablation.rs; every one
//! bit-identical to `Scalar`):
//! * `Scalar`     — word-at-a-time u32, the paper's reference C loop
//! * `Word64`     — pairs u32 words into u64 (half the popcnt ops)
//! * `Blocked`    — Word64 + 4-column register blocking
//! * `Blocked2x4` — 2 w-rows x 4 x-rows register blocking
//! * `Wide`       — portable `[u64; 4]`-wide kernel with 4-column
//!   blocking (SIMD fallback tier)
//! * `Simd`       — the 256-bit tier (AVX2, else `Wide`)
//! * `Avx512`     — the 512-bit tier (`vpxorq` + `VPOPCNTDQ`, else the
//!   AVX512BW nibble-LUT variant, else falls back through `Simd`)
//! * `Threaded`   — widest-tier tiles split 2-D (rows x columns)
//!   across threads, so small-D layers still scale
//! * `Auto`       — resolved per shape (heuristic table, or one-shot
//!   microbench via [`XnorImpl::calibrate`]) — the plan-time default
//!
//! Threading runs either on scoped threads (the free-function path) or
//! on a persistent [`ThreadPool`] via [`xnor_gemm_pooled`] — the
//! plan/session serving path owns such a pool so steady-state inference
//! never spawns.

use crate::tensor::PackedMatrix;
use crate::utils::threadpool::{scope_chunks, ThreadPool};

use super::simd;

/// Which xnor-gemm implementation to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum XnorImpl {
    /// Word-at-a-time u32 loop — the paper's reference C kernel and the
    /// bit-exactness oracle for every other tier.
    Scalar,
    /// u32 words paired into u64 (half the popcnt ops).
    Word64,
    /// `Word64` + 4-column register blocking.
    Blocked,
    /// 2 w-rows x 4 x-rows register blocking.
    Blocked2x4,
    /// Portable `[u64; 4]`-wide kernel (always available).
    Wide,
    /// The 256-bit SIMD tier (AVX2 -> `Wide` fallback).
    Simd,
    /// The 512-bit SIMD tier: `vpxorq` + `VPOPCNTDQ` when the CPU has
    /// it, else the AVX512BW nibble-LUT variant, else the `Simd`
    /// fallback chain — always safe to request, detection-gated inside.
    Avx512,
    /// Shape-aware choice, resolved at dispatch/plan time.
    Auto,
    /// Widest-tier tiles split across `n` threads (2-D row x column
    /// grid).
    Threaded(usize),
}

/// Work (in packed words, `D * N * kw`) below which threading is not
/// worth a wakeup: at the wide kernel's throughput this is a few µs,
/// comparable to waking the pool.
const THREAD_WORDS: usize = 1 << 17;

/// Auto never picks more threads than this (diminishing returns on the
/// shared-memory reduction; the serving layer owns cross-request
/// parallelism).
const MAX_AUTO_THREADS: usize = 16;

/// Host parallelism, clamped for `Auto` resolution.
fn auto_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(MAX_AUTO_THREADS)
}

impl XnorImpl {
    /// Every single-threaded implementation (differential-fuzz and
    /// ablation coverage; `Auto`/`Threaded` are derived from these).
    /// `Avx512` is in the list unconditionally — on CPUs without
    /// AVX-512 it falls back through the `Simd` chain, staying
    /// bit-identical.
    pub const ALL_SINGLE: [XnorImpl; 7] = [
        XnorImpl::Scalar,
        XnorImpl::Word64,
        XnorImpl::Blocked,
        XnorImpl::Blocked2x4,
        XnorImpl::Wide,
        XnorImpl::Simd,
        XnorImpl::Avx512,
    ];

    /// Implementation label.  Borrowed (allocation-free) for every
    /// variant except `Threaded`, whose thread count is dynamic —
    /// metrics labels sit on the request path.
    pub fn name(&self) -> std::borrow::Cow<'static, str> {
        match self {
            XnorImpl::Scalar => "scalar32".into(),
            XnorImpl::Word64 => "word64".into(),
            XnorImpl::Blocked => "blocked".into(),
            XnorImpl::Blocked2x4 => "blocked2x4".into(),
            XnorImpl::Wide => "wide64".into(),
            XnorImpl::Simd => "simd".into(),
            XnorImpl::Avx512 => "avx512".into(),
            XnorImpl::Auto => "auto".into(),
            XnorImpl::Threaded(n) => format!("threaded{n}").into(),
        }
    }

    /// Inverse of [`XnorImpl::name`]: parse a stored label back into
    /// an impl (the calibration cache persists choices by label so the
    /// file stays human-readable).  Unknown labels — e.g. from a
    /// future arm — return `None` and the caller re-calibrates.
    pub fn from_name(name: &str) -> Option<XnorImpl> {
        Some(match name {
            "scalar32" => XnorImpl::Scalar,
            "word64" => XnorImpl::Word64,
            "blocked" => XnorImpl::Blocked,
            "blocked2x4" => XnorImpl::Blocked2x4,
            "wide64" => XnorImpl::Wide,
            "simd" => XnorImpl::Simd,
            "avx512" => XnorImpl::Avx512,
            "auto" => XnorImpl::Auto,
            other => {
                let t: usize =
                    other.strip_prefix("threaded")?.parse().ok()?;
                XnorImpl::Threaded(t)
            }
        })
    }

    /// Resolve `Auto` into a concrete impl for a `[D, k] x [N, k]` gemm
    /// (identity on everything else).  The heuristic table:
    /// single-thread `Simd` for small problems, 2-D tiled `Threaded`
    /// once the popcount work amortizes a pool wakeup.  Plan
    /// compilation calls this once per op; `xnor_gemm` also applies it
    /// so `Auto` is always a valid argument.
    pub fn resolve(self, d: usize, k: usize, n: usize) -> XnorImpl {
        match self {
            XnorImpl::Auto => {
                let kw = k.div_ceil(32);
                let work = d * n * kw;
                let t = auto_threads();
                if t > 1 && work >= THREAD_WORDS {
                    XnorImpl::Threaded(t)
                } else if simd::avx512_available() {
                    XnorImpl::Avx512
                } else {
                    XnorImpl::Simd
                }
            }
            other => other,
        }
    }

    /// One-shot microbench calibration: time each candidate on a
    /// synthetic `[d, k] x [n, k]` problem (one warmup + two reps, min
    /// taken) and return the fastest.  Costs a few ms per shape — the
    /// opt-in alternative to the [`XnorImpl::resolve`] heuristic for
    /// plan compilation (`BITKERNEL_CALIBRATE=1`) and the bench reports.
    ///
    /// `Threaded` is timed through a warm [`ThreadPool`] — the
    /// execution mode the plan would actually use — not through
    /// per-call scoped spawns, so the comparison is not biased against
    /// threading by spawn overhead the serving path never pays.
    pub fn calibrate(d: usize, k: usize, n: usize) -> XnorImpl {
        use crate::utils::{Rng, Stopwatch};
        let mut rng = Rng::new(0xB17C0DE);
        let w = super::pack::pack_rows(&rng.sign_vec(d * k), d, k);
        let x = super::pack::pack_rows(&rng.sign_vec(n * k), n, k);
        let mut out = vec![0i32; d * n];
        let mut candidates = vec![
            XnorImpl::Blocked,
            XnorImpl::Blocked2x4,
            XnorImpl::Wide,
            XnorImpl::Simd,
        ];
        if simd::avx512_available() {
            candidates.push(XnorImpl::Avx512);
        }
        let t = auto_threads();
        let pool = (t > 1).then(|| ThreadPool::new(t));
        if pool.is_some() {
            candidates.push(XnorImpl::Threaded(t));
        }
        let mut best = (f64::INFINITY, XnorImpl::Simd);
        for imp in candidates {
            let mut run = |out: &mut [i32]| match &pool {
                Some(p) => xnor_gemm_pooled(&w, &x, out, imp, p),
                None => xnor_gemm(&w, &x, out, imp),
            };
            run(&mut out); // warmup
            let mut t_min = f64::INFINITY;
            for _ in 0..2 {
                let sw = Stopwatch::start();
                run(&mut out);
                t_min = t_min.min(sw.elapsed_secs());
            }
            if t_min < best.0 {
                best = (t_min, imp);
            }
        }
        best.1
    }
}

/// Popcount of the xnor of two packed rows (u32 at a time).
#[inline]
fn popc_xnor_u32(a: &[u32], b: &[u32]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0u32;
    for (&wa, &wb) in a.iter().zip(b.iter()) {
        acc += (!(wa ^ wb)).count_ones();
    }
    acc
}

/// Popcount of the xnor of two packed rows, u64 at a time.
#[inline]
fn popc_xnor_u64(a: &[u32], b: &[u32]) -> u32 {
    let mut acc = 0u32;
    let (a2, ra) = a.split_at(a.len() & !1);
    let (b2, rb) = b.split_at(b.len() & !1);
    for (pa, pb) in a2.chunks_exact(2).zip(b2.chunks_exact(2)) {
        let wa = (pa[0] as u64) | ((pa[1] as u64) << 32);
        let wb = (pb[0] as u64) | ((pb[1] as u64) << 32);
        acc += (!(wa ^ wb)).count_ones();
    }
    if let (Some(&wa), Some(&wb)) = (ra.first(), rb.first()) {
        acc += (!(wa ^ wb)).count_ones();
    }
    acc
}

/// `2*popc - 32*kw - pad`: the packed-word identity, shared by every
/// implementation tier (including `super::simd`).
#[inline]
pub(crate) fn finish(popc: u32, kw: usize, pad: i32) -> i32 {
    2 * popc as i32 - 32 * kw as i32 - pad
}

fn gemm_scalar(w: &PackedMatrix, x: &PackedMatrix, out: &mut [i32]) {
    let (kw, pad) = (w.kw, w.pad_bits());
    for i in 0..w.rows {
        let wrow = w.row(i);
        let orow = &mut out[i * x.rows..(i + 1) * x.rows];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = finish(popc_xnor_u32(wrow, x.row(j)), kw, pad);
        }
    }
}

fn gemm_word64(w: &PackedMatrix, x: &PackedMatrix, out: &mut [i32]) {
    let (kw, pad) = (w.kw, w.pad_bits());
    for i in 0..w.rows {
        let wrow = w.row(i);
        let orow = &mut out[i * x.rows..(i + 1) * x.rows];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = finish(popc_xnor_u64(wrow, x.row(j)), kw, pad);
        }
    }
}

/// Register-blocked kernel body for rows `i_lo..i_hi` of `w`.
///
/// Processes 4 x-rows per inner sweep so each loaded w-word is reused 4
/// times from a register; the reduction runs u64-at-a-time.
fn gemm_blocked_rows(
    w: &PackedMatrix,
    x: &PackedMatrix,
    out: &mut [i32],
    i_lo: usize,
    i_hi: usize,
) {
    let (kw, pad) = (w.kw, w.pad_bits());
    let n = x.rows;
    let n4 = n & !3;
    for i in i_lo..i_hi {
        let wrow = w.row(i);
        let orow = &mut out[(i - i_lo) * n..(i - i_lo + 1) * n];
        let mut j = 0;
        while j < n4 {
            let x0 = x.row(j);
            let x1 = x.row(j + 1);
            let x2 = x.row(j + 2);
            let x3 = x.row(j + 3);
            let (mut a0, mut a1, mut a2, mut a3) = (0u32, 0u32, 0u32, 0u32);
            let full2 = kw & !1;
            let mut wi = 0;
            while wi < full2 {
                let ww = (wrow[wi] as u64) | ((wrow[wi + 1] as u64) << 32);
                a0 += (!(ww ^ ((x0[wi] as u64) | ((x0[wi + 1] as u64) << 32))))
                    .count_ones();
                a1 += (!(ww ^ ((x1[wi] as u64) | ((x1[wi + 1] as u64) << 32))))
                    .count_ones();
                a2 += (!(ww ^ ((x2[wi] as u64) | ((x2[wi + 1] as u64) << 32))))
                    .count_ones();
                a3 += (!(ww ^ ((x3[wi] as u64) | ((x3[wi + 1] as u64) << 32))))
                    .count_ones();
                wi += 2;
            }
            if wi < kw {
                let ww = wrow[wi];
                a0 += (!(ww ^ x0[wi])).count_ones();
                a1 += (!(ww ^ x1[wi])).count_ones();
                a2 += (!(ww ^ x2[wi])).count_ones();
                a3 += (!(ww ^ x3[wi])).count_ones();
            }
            orow[j] = finish(a0, kw, pad);
            orow[j + 1] = finish(a1, kw, pad);
            orow[j + 2] = finish(a2, kw, pad);
            orow[j + 3] = finish(a3, kw, pad);
            j += 4;
        }
        while j < n {
            orow[j] = finish(popc_xnor_u64(wrow, x.row(j)), kw, pad);
            j += 1;
        }
    }
}

fn gemm_blocked(w: &PackedMatrix, x: &PackedMatrix, out: &mut [i32]) {
    gemm_blocked_rows(w, x, out, 0, w.rows);
}

/// 2x4 register blocking: two w-rows share every loaded x-word (halves
/// x-side loads vs the 1x4 `Blocked`).  §Perf experiment; ablated in
/// benches/ablation.rs.
fn gemm_blocked2x4(w: &PackedMatrix, x: &PackedMatrix, out: &mut [i32]) {
    let (kw, pad) = (w.kw, w.pad_bits());
    let n = x.rows;
    let rows = w.rows;
    let r2 = rows & !1;
    let n4 = n & !3;
    let mut i = 0;
    while i < r2 {
        let w0 = w.row(i);
        let w1 = w.row(i + 1);
        let mut j = 0;
        while j < n4 {
            let x0 = x.row(j);
            let x1 = x.row(j + 1);
            let x2 = x.row(j + 2);
            let x3 = x.row(j + 3);
            let mut acc = [0u32; 8];
            let full2 = kw & !1;
            let mut wi = 0;
            while wi < full2 {
                let wa = (w0[wi] as u64) | ((w0[wi + 1] as u64) << 32);
                let wb = (w1[wi] as u64) | ((w1[wi + 1] as u64) << 32);
                let xa = (x0[wi] as u64) | ((x0[wi + 1] as u64) << 32);
                let xb = (x1[wi] as u64) | ((x1[wi + 1] as u64) << 32);
                let xc = (x2[wi] as u64) | ((x2[wi + 1] as u64) << 32);
                let xd = (x3[wi] as u64) | ((x3[wi + 1] as u64) << 32);
                acc[0] += (!(wa ^ xa)).count_ones();
                acc[1] += (!(wa ^ xb)).count_ones();
                acc[2] += (!(wa ^ xc)).count_ones();
                acc[3] += (!(wa ^ xd)).count_ones();
                acc[4] += (!(wb ^ xa)).count_ones();
                acc[5] += (!(wb ^ xb)).count_ones();
                acc[6] += (!(wb ^ xc)).count_ones();
                acc[7] += (!(wb ^ xd)).count_ones();
                wi += 2;
            }
            if wi < kw {
                for (r, wrow) in [w0, w1].into_iter().enumerate() {
                    let ww = wrow[wi];
                    acc[r * 4] += (!(ww ^ x0[wi])).count_ones();
                    acc[r * 4 + 1] += (!(ww ^ x1[wi])).count_ones();
                    acc[r * 4 + 2] += (!(ww ^ x2[wi])).count_ones();
                    acc[r * 4 + 3] += (!(ww ^ x3[wi])).count_ones();
                }
            }
            for r in 0..2 {
                for c in 0..4 {
                    out[(i + r) * n + j + c] =
                        finish(acc[r * 4 + c], kw, pad);
                }
            }
            j += 4;
        }
        while j < n {
            out[i * n + j] = finish(popc_xnor_u64(w0, x.row(j)), kw, pad);
            out[(i + 1) * n + j] =
                finish(popc_xnor_u64(w1, x.row(j)), kw, pad);
            j += 1;
        }
        i += 2;
    }
    if i < rows {
        // Odd final row: reuse the 1x4 kernel on the tail slice.
        let tail = &mut out[i * n..];
        gemm_blocked_rows(w, x, tail, i, rows);
    }
}

fn gemm_wide(w: &PackedMatrix, x: &PackedMatrix, out: &mut [i32]) {
    // SAFETY: out covers the full [rows, n] block, single caller.
    unsafe {
        simd::gemm_tile_wide(w, x, out.as_mut_ptr(), x.rows, 0, w.rows,
                             0, x.rows);
    }
}

fn gemm_simd(w: &PackedMatrix, x: &PackedMatrix, out: &mut [i32]) {
    // SAFETY: out covers the full [rows, n] block, single caller.
    unsafe {
        simd::gemm_tile_avx2_or_wide(w, x, out.as_mut_ptr(), x.rows, 0,
                                     w.rows, 0, x.rows);
    }
}

fn gemm_avx512(w: &PackedMatrix, x: &PackedMatrix, out: &mut [i32]) {
    // SAFETY: out covers the full [rows, n] block, single caller.
    unsafe {
        simd::gemm_tile_avx512(w, x, out.as_mut_ptr(), x.rows, 0,
                               w.rows, 0, x.rows);
    }
}

/// Raw output pointer shared across worker tiles.  Sound because the
/// tile grid below assigns every `out[i*n + j]` cell to exactly one
/// tile, and the drivers join all workers before returning.
struct OutPtr(*mut i32);
unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

/// 2-D tile grid for a `[rows, n]` output split across `threads`
/// workers: rows split first, then columns until there are at least two
/// tiles per worker (load balance for small-D layers), with column
/// tiles kept >= 4 wide for the kernels' 4-column blocking.
fn tile_grid(rows: usize, n: usize, threads: usize) -> (usize, usize) {
    let row_tiles = rows.min(threads).max(1);
    // row_tiles <= threads < 2*threads, so columns always split at
    // least 2-ways (when n allows) to reach ~2 tiles per worker.
    let col_tiles = (2 * threads)
        .div_ceil(row_tiles)
        .min(n.div_ceil(4))
        .max(1);
    (row_tiles, col_tiles)
}

/// Threaded driver: `Simd` tiles over a 2-D row x column grid, run
/// either on scoped threads (`pool: None`) or on a persistent pool.
fn gemm_tiled(
    w: &PackedMatrix,
    x: &PackedMatrix,
    out: &mut [i32],
    threads: usize,
    pool: Option<&ThreadPool>,
) {
    let rows = w.rows;
    let n = x.rows;
    if rows == 0 || n == 0 {
        return;
    }
    let t = threads.max(1).min(rows * n);
    if t == 1 {
        gemm_simd(w, x, out);
        return;
    }
    let (row_tiles, col_tiles) = tile_grid(rows, n, t);
    let tr = rows.div_ceil(row_tiles);
    let tc = n.div_ceil(col_tiles);
    let tiles = row_tiles * col_tiles;
    let optr = OutPtr(out.as_mut_ptr());
    let run = |lo: usize, hi: usize| {
        for tile in lo..hi {
            let (ri, ci) = (tile / col_tiles, tile % col_tiles);
            let i_lo = ri * tr;
            let i_hi = ((ri + 1) * tr).min(rows);
            let j_lo = ci * tc;
            let j_hi = ((ci + 1) * tc).min(n);
            if i_lo >= i_hi || j_lo >= j_hi {
                continue;
            }
            // SAFETY: tiles are disjoint rectangles of the [rows, n]
            // output; the driver below joins before `out` is released.
            unsafe {
                simd::gemm_tile_best(w, x, optr.0, n, i_lo, i_hi, j_lo,
                                     j_hi);
            }
        }
    };
    match pool {
        Some(p) => p.run_chunks(tiles, &run),
        None => scope_chunks(tiles, t, run),
    }
}

/// Packed gemm dispatch: `out[i * x.rows + j] = <w_i, x_j>` exactly.
///
/// `w`: [D, k] packed, `x`: [N, k] packed (im2col transposed), `out`
/// must have `w.rows * x.rows` elements.  `Auto` resolves per call via
/// [`XnorImpl::resolve`]; `Threaded` uses scoped threads here — the
/// plan/session path uses [`xnor_gemm_pooled`] instead so steady-state
/// serving never spawns.
pub fn xnor_gemm(
    w: &PackedMatrix,
    x: &PackedMatrix,
    out: &mut [i32],
    imp: XnorImpl,
) {
    assert_eq!(w.k, x.k, "reduction length mismatch");
    assert_eq!(w.kw, x.kw);
    assert_eq!(out.len(), w.rows * x.rows, "output size");
    match imp.resolve(w.rows, w.k, x.rows) {
        XnorImpl::Scalar => gemm_scalar(w, x, out),
        XnorImpl::Word64 => gemm_word64(w, x, out),
        XnorImpl::Blocked => gemm_blocked(w, x, out),
        XnorImpl::Blocked2x4 => gemm_blocked2x4(w, x, out),
        XnorImpl::Wide => gemm_wide(w, x, out),
        XnorImpl::Simd => gemm_simd(w, x, out),
        XnorImpl::Avx512 => gemm_avx512(w, x, out),
        XnorImpl::Threaded(t) => gemm_tiled(w, x, out, t, None),
        XnorImpl::Auto => unreachable!("resolve() returns concrete impls"),
    }
}

/// [`xnor_gemm`] with `Threaded` work running on `pool`'s persistent
/// workers (the plan/session serving path) instead of per-call scoped
/// spawns.  Bit-identical to [`xnor_gemm`] for every impl.
pub fn xnor_gemm_pooled(
    w: &PackedMatrix,
    x: &PackedMatrix,
    out: &mut [i32],
    imp: XnorImpl,
    pool: &ThreadPool,
) {
    assert_eq!(w.k, x.k, "reduction length mismatch");
    assert_eq!(w.kw, x.kw);
    assert_eq!(out.len(), w.rows * x.rows, "output size");
    match imp.resolve(w.rows, w.k, x.rows) {
        XnorImpl::Threaded(t) => gemm_tiled(w, x, out, t, Some(pool)),
        concrete => xnor_gemm(w, x, out, concrete),
    }
}

/// Combine the two plane gemms into the ternary dot products.
///
/// With `pos[i,j] = +1` iff `w[i,j] > 0` (else `-1`) and
/// `neg[i,j] = +1` iff `w[i,j] < 0`, each element contributes
/// `(p - n) / 2 ∈ {-1, 0, +1}` — exactly the ternary weight — so
/// `<w_i, x_j> = (<pos_i, x_j> - <neg_i, x_j>) / 2`, and the
/// difference is always even (each element contributes ±2 or 0).
/// Integer arithmetic: bit-identical across every impl by
/// construction.
#[inline]
fn ternary_combine(out: &mut [i32], scratch: &[i32]) {
    for (o, &s) in out.iter_mut().zip(scratch.iter()) {
        *o = (*o - s) / 2;
    }
}

/// Two-plane ternary gemm: `out[i * x.rows + j] = <w_i, x_j>` exactly,
/// for ternary weights `{-1, 0, +1}` packed as a positive plane
/// (`bit 1` iff `w > 0`) and a negative plane (`bit 1` iff `w < 0`).
///
/// Runs [`xnor_gemm`] once per plane (`scratch` holds the negative
/// plane's gemm; same length as `out`) and combines.  `Auto` resolves
/// once so both planes run the same impl.
pub fn ternary_gemm(
    pos: &PackedMatrix,
    neg: &PackedMatrix,
    x: &PackedMatrix,
    out: &mut [i32],
    scratch: &mut [i32],
    imp: XnorImpl,
) {
    assert_eq!(pos.rows, neg.rows, "plane row mismatch");
    assert_eq!(pos.k, neg.k, "plane k mismatch");
    assert_eq!(scratch.len(), out.len(), "scratch size");
    let imp = imp.resolve(pos.rows, pos.k, x.rows);
    xnor_gemm(pos, x, out, imp);
    xnor_gemm(neg, x, scratch, imp);
    ternary_combine(out, scratch);
}

/// [`ternary_gemm`] with `Threaded` work running on `pool`'s
/// persistent workers (see [`xnor_gemm_pooled`]).  Bit-identical to
/// [`ternary_gemm`] for every impl.
pub fn ternary_gemm_pooled(
    pos: &PackedMatrix,
    neg: &PackedMatrix,
    x: &PackedMatrix,
    out: &mut [i32],
    scratch: &mut [i32],
    imp: XnorImpl,
    pool: &ThreadPool,
) {
    assert_eq!(pos.rows, neg.rows, "plane row mismatch");
    assert_eq!(pos.k, neg.k, "plane k mismatch");
    assert_eq!(scratch.len(), out.len(), "scratch size");
    let imp = imp.resolve(pos.rows, pos.k, x.rows);
    xnor_gemm_pooled(pos, x, out, imp, pool);
    xnor_gemm_pooled(neg, x, scratch, imp, pool);
    ternary_combine(out, scratch);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::pack::pack_rows;
    use crate::utils::Rng;

    fn dense_dot(a: &[f32], b: &[f32]) -> i32 {
        a.iter().zip(b).map(|(x, y)| (x * y) as i32).sum()
    }

    fn all_impls() -> Vec<XnorImpl> {
        let mut v = XnorImpl::ALL_SINGLE.to_vec();
        v.push(XnorImpl::Auto);
        v.push(XnorImpl::Threaded(3));
        v
    }

    fn check_all_impls(d: usize, k: usize, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let wm = rng.sign_vec(d * k);
        let xm = rng.sign_vec(n * k);
        let w = pack_rows(&wm, d, k);
        let x = pack_rows(&xm, n, k);

        let mut want = vec![0i32; d * n];
        for i in 0..d {
            for j in 0..n {
                want[i * n + j] =
                    dense_dot(&wm[i * k..(i + 1) * k], &xm[j * k..(j + 1) * k]);
            }
        }
        for imp in all_impls() {
            let mut got = vec![0i32; d * n];
            xnor_gemm(&w, &x, &mut got, imp);
            assert_eq!(got, want, "impl {:?} d={d} k={k} n={n}", imp);
        }
    }

    #[test]
    fn table1_word_identity() {
        // 2*popcount(~(a^b)) - 32 == dot of the +-1 interpretations.
        let mut rng = Rng::new(0);
        for _ in 0..200 {
            let a = rng.next_u32();
            let b = rng.next_u32();
            let mut dot = 0i32;
            for i in 0..32 {
                let va = if (a >> i) & 1 == 1 { 1 } else { -1 };
                let vb = if (b >> i) & 1 == 1 { 1 } else { -1 };
                dot += va * vb;
            }
            assert_eq!(2 * (!(a ^ b)).count_ones() as i32 - 32, dot);
        }
    }

    #[test]
    fn exact_small_shapes() {
        for (d, k, n) in [(1, 1, 1), (2, 31, 3), (3, 32, 5), (4, 33, 4),
                          (5, 70, 7), (8, 64, 8)] {
            check_all_impls(d, k, n, (d * 1000 + k * 10 + n) as u64);
        }
    }

    #[test]
    fn exact_layer_shape() {
        // A real BNN gemm: conv3 at scale 0.25 (D=64, K=288, N=64).
        check_all_impls(64, 288, 64, 42);
    }

    #[test]
    fn extremes() {
        for k in [1usize, 31, 32, 33, 95] {
            let ones = vec![1.0f32; k];
            let mones = vec![-1.0f32; k];
            let w = pack_rows(&ones, 1, k);
            let xs = pack_rows(&[ones.clone(), mones].concat(), 2, k);
            for imp in [XnorImpl::Blocked, XnorImpl::Wide, XnorImpl::Simd] {
                let mut out = vec![0i32; 2];
                xnor_gemm(&w, &xs, &mut out, imp);
                assert_eq!(out, vec![k as i32, -(k as i32)],
                           "k={k} {imp:?}");
            }
        }
    }

    #[test]
    fn threaded_more_threads_than_rows() {
        check_all_impls(2, 40, 3, 7); // Threaded(3) > 2 rows inside
        let mut rng = Rng::new(9);
        let wm = rng.sign_vec(2 * 40);
        let xm = rng.sign_vec(3 * 40);
        let w = pack_rows(&wm, 2, 40);
        let x = pack_rows(&xm, 3, 40);
        let mut a = vec![0i32; 6];
        let mut b = vec![0i32; 6];
        xnor_gemm(&w, &x, &mut a, XnorImpl::Threaded(64));
        xnor_gemm(&w, &x, &mut b, XnorImpl::Scalar);
        assert_eq!(a, b);
    }

    #[test]
    fn pooled_matches_scoped() {
        let pool = ThreadPool::new(3);
        let mut rng = Rng::new(31);
        for (d, k, n) in [(5, 70, 9), (64, 288, 33), (2, 31, 1)] {
            let w = pack_rows(&rng.sign_vec(d * k), d, k);
            let x = pack_rows(&rng.sign_vec(n * k), n, k);
            let mut want = vec![0i32; d * n];
            xnor_gemm(&w, &x, &mut want, XnorImpl::Scalar);
            for imp in [XnorImpl::Threaded(3), XnorImpl::Auto,
                        XnorImpl::Simd] {
                let mut got = vec![0i32; d * n];
                xnor_gemm_pooled(&w, &x, &mut got, imp, &pool);
                assert_eq!(got, want, "{imp:?} d={d} k={k} n={n}");
            }
        }
    }

    #[test]
    fn tile_grid_covers_and_balances() {
        // Small-D case (the motivating one): D=64 on 8 threads must
        // produce more than 8 tiles so columns share the work.
        let (rt, ct) = tile_grid(64, 1024, 8);
        assert!(rt * ct >= 16, "{rt}x{ct}");
        // Degenerate shapes stay valid.
        assert_eq!(tile_grid(1, 1, 8).0, 1);
        assert!(tile_grid(1, 3, 8).1 <= 1);
        let (rt, ct) = tile_grid(2, 1000, 4);
        assert!(rt <= 2 && ct >= 1);
    }

    #[test]
    fn name_round_trips_through_from_name() {
        let mut all = all_impls();
        all.push(XnorImpl::Threaded(16));
        for imp in all {
            assert_eq!(XnorImpl::from_name(&imp.name()), Some(imp));
        }
        assert_eq!(XnorImpl::from_name("avx1024"), None);
        assert_eq!(XnorImpl::from_name("threadedx"), None);
        assert_eq!(XnorImpl::from_name(""), None);
    }

    #[test]
    fn auto_resolves_to_concrete() {
        // tiny problem -> the widest single-thread SIMD tier
        let want = if simd::avx512_available() {
            XnorImpl::Avx512
        } else {
            XnorImpl::Simd
        };
        assert_eq!(XnorImpl::Auto.resolve(4, 32, 4), want);
        // huge problem -> Threaded iff the host has >1 core
        let r = XnorImpl::Auto.resolve(512, 4608, 4096);
        match r {
            XnorImpl::Threaded(t) => assert!(t >= 2),
            XnorImpl::Simd | XnorImpl::Avx512 => {
                assert_eq!(super::auto_threads(), 1, "expected Threaded")
            }
            other => panic!("unexpected {other:?}"),
        }
        // non-Auto is identity
        assert_eq!(XnorImpl::Blocked.resolve(512, 4608, 4096),
                   XnorImpl::Blocked);
    }

    #[test]
    fn calibrate_returns_valid_single_or_threaded() {
        let imp = XnorImpl::calibrate(8, 64, 16);
        assert!(XnorImpl::ALL_SINGLE.contains(&imp)
                    || matches!(imp, XnorImpl::Threaded(_)),
                "{imp:?}");
    }

    #[test]
    #[should_panic(expected = "reduction length mismatch")]
    fn rejects_k_mismatch() {
        let w = PackedMatrix::zeros(1, 32);
        let x = PackedMatrix::zeros(1, 64);
        xnor_gemm(&w, &x, &mut [0], XnorImpl::Scalar);
    }

    #[test]
    fn ternary_matches_dense_dot() {
        let mut rng = Rng::new(77);
        let pool = ThreadPool::new(3);
        for (d, k, n) in [(1, 1, 1), (3, 31, 5), (4, 33, 7), (5, 70, 9)] {
            // ternary weights in {-1, 0, +1}, sign activations
            let wm: Vec<f32> =
                (0..d * k).map(|_| rng.below(3) as f32 - 1.0).collect();
            let xm = rng.sign_vec(n * k);
            let pos: Vec<f32> = wm
                .iter()
                .map(|&v| if v > 0.0 { 1.0 } else { -1.0 })
                .collect();
            let negv: Vec<f32> = wm
                .iter()
                .map(|&v| if v < 0.0 { 1.0 } else { -1.0 })
                .collect();
            let pp = pack_rows(&pos, d, k);
            let np = pack_rows(&negv, d, k);
            let x = pack_rows(&xm, n, k);
            let mut want = vec![0i32; d * n];
            for i in 0..d {
                for j in 0..n {
                    want[i * n + j] = dense_dot(&wm[i * k..(i + 1) * k],
                                                &xm[j * k..(j + 1) * k]);
                }
            }
            for imp in all_impls() {
                let mut got = vec![0i32; d * n];
                let mut scratch = vec![0i32; d * n];
                ternary_gemm(&pp, &np, &x, &mut got, &mut scratch, imp);
                assert_eq!(got, want, "impl {imp:?} d={d} k={k} n={n}");
                got.fill(0);
                ternary_gemm_pooled(&pp, &np, &x, &mut got, &mut scratch,
                                    imp, &pool);
                assert_eq!(got, want, "pooled {imp:?} d={d} k={k} n={n}");
            }
        }
    }

    #[test]
    fn output_parity_and_range() {
        let k = 77;
        let mut rng = Rng::new(5);
        let w = pack_rows(&rng.sign_vec(4 * k), 4, k);
        let x = pack_rows(&rng.sign_vec(6 * k), 6, k);
        let mut out = vec![0i32; 24];
        xnor_gemm(&w, &x, &mut out, XnorImpl::Word64);
        for &v in &out {
            assert!(v.abs() <= k as i32);
            assert_eq!(v.rem_euclid(2), k as i32 % 2);
        }
    }
}
