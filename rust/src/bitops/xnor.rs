//! The xnor-bitcount gemm (paper Sec. 3.2), four implementations.
//!
//! All compute, for packed operands `w` ([D, k] logical) and `x`
//! ([N, k] logical — the im2col matrix transposed so its reduction is
//! contiguous):
//!
//! ```text
//!     out[i, j] = sum_over_words( 2 * popcount(~(w[i,w] ^ x[j,w])) - 32 )
//!                 - pad_bits
//! ```
//!
//! which equals the float dot product of the underlying {-1,+1} rows
//! exactly.  `popcount` compiles to the hardware `popcnt` instruction
//! (the paper uses libpopcnt / CUDA `__popc`).
//!
//! Implementations (ablated in benches/ablation.rs):
//! * `Scalar`   — word-at-a-time u32, the paper's reference C loop
//! * `Word64`   — pairs u32 words into u64 (half the popcnt ops)
//! * `Blocked`  — Word64 + 4-column register blocking (reuses the loaded
//!   w-word across 4 x-rows, cutting w-side loads 4x)
//! * `Threaded` — Blocked split over output rows via scoped threads

use crate::tensor::PackedMatrix;

/// Which xnor-gemm implementation to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum XnorImpl {
    Scalar,
    Word64,
    Blocked,
    /// 2 w-rows x 4 x-rows register blocking.
    Blocked2x4,
    /// Blocked, split across `n` threads.
    Threaded(usize),
}

impl XnorImpl {
    pub const ALL_SINGLE: [XnorImpl; 3] =
        [XnorImpl::Scalar, XnorImpl::Word64, XnorImpl::Blocked];

    /// Implementation label.  Borrowed (allocation-free) for every
    /// variant except `Threaded`, whose thread count is dynamic —
    /// metrics labels sit on the request path.
    pub fn name(&self) -> std::borrow::Cow<'static, str> {
        match self {
            XnorImpl::Scalar => "scalar32".into(),
            XnorImpl::Word64 => "word64".into(),
            XnorImpl::Blocked => "blocked".into(),
            XnorImpl::Blocked2x4 => "blocked2x4".into(),
            XnorImpl::Threaded(n) => format!("threaded{n}").into(),
        }
    }
}

/// Popcount of the xnor of two packed rows (u32 at a time).
#[inline]
fn popc_xnor_u32(a: &[u32], b: &[u32]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0u32;
    for (&wa, &wb) in a.iter().zip(b.iter()) {
        acc += (!(wa ^ wb)).count_ones();
    }
    acc
}

/// Popcount of the xnor of two packed rows, u64 at a time.
#[inline]
fn popc_xnor_u64(a: &[u32], b: &[u32]) -> u32 {
    let mut acc = 0u32;
    let (a2, ra) = a.split_at(a.len() & !1);
    let (b2, rb) = b.split_at(b.len() & !1);
    for (pa, pb) in a2.chunks_exact(2).zip(b2.chunks_exact(2)) {
        let wa = (pa[0] as u64) | ((pa[1] as u64) << 32);
        let wb = (pb[0] as u64) | ((pb[1] as u64) << 32);
        acc += (!(wa ^ wb)).count_ones();
    }
    if let (Some(&wa), Some(&wb)) = (ra.first(), rb.first()) {
        acc += (!(wa ^ wb)).count_ones();
    }
    acc
}

#[inline]
fn finish(popc: u32, kw: usize, pad: i32) -> i32 {
    2 * popc as i32 - 32 * kw as i32 - pad
}

fn gemm_scalar(w: &PackedMatrix, x: &PackedMatrix, out: &mut [i32]) {
    let (kw, pad) = (w.kw, w.pad_bits());
    for i in 0..w.rows {
        let wrow = w.row(i);
        let orow = &mut out[i * x.rows..(i + 1) * x.rows];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = finish(popc_xnor_u32(wrow, x.row(j)), kw, pad);
        }
    }
}

fn gemm_word64(w: &PackedMatrix, x: &PackedMatrix, out: &mut [i32]) {
    let (kw, pad) = (w.kw, w.pad_bits());
    for i in 0..w.rows {
        let wrow = w.row(i);
        let orow = &mut out[i * x.rows..(i + 1) * x.rows];
        for (j, o) in orow.iter_mut().enumerate() {
            *o = finish(popc_xnor_u64(wrow, x.row(j)), kw, pad);
        }
    }
}

/// Register-blocked kernel body for rows `i_lo..i_hi` of `w`.
///
/// Processes 4 x-rows per inner sweep so each loaded w-word is reused 4
/// times from a register; the reduction runs u64-at-a-time.
fn gemm_blocked_rows(
    w: &PackedMatrix,
    x: &PackedMatrix,
    out: &mut [i32],
    i_lo: usize,
    i_hi: usize,
) {
    let (kw, pad) = (w.kw, w.pad_bits());
    let n = x.rows;
    let n4 = n & !3;
    for i in i_lo..i_hi {
        let wrow = w.row(i);
        let orow = &mut out[(i - i_lo) * n..(i - i_lo + 1) * n];
        let mut j = 0;
        while j < n4 {
            let x0 = x.row(j);
            let x1 = x.row(j + 1);
            let x2 = x.row(j + 2);
            let x3 = x.row(j + 3);
            let (mut a0, mut a1, mut a2, mut a3) = (0u32, 0u32, 0u32, 0u32);
            let full2 = kw & !1;
            let mut wi = 0;
            while wi < full2 {
                let ww = (wrow[wi] as u64) | ((wrow[wi + 1] as u64) << 32);
                a0 += (!(ww ^ ((x0[wi] as u64) | ((x0[wi + 1] as u64) << 32))))
                    .count_ones();
                a1 += (!(ww ^ ((x1[wi] as u64) | ((x1[wi + 1] as u64) << 32))))
                    .count_ones();
                a2 += (!(ww ^ ((x2[wi] as u64) | ((x2[wi + 1] as u64) << 32))))
                    .count_ones();
                a3 += (!(ww ^ ((x3[wi] as u64) | ((x3[wi + 1] as u64) << 32))))
                    .count_ones();
                wi += 2;
            }
            if wi < kw {
                let ww = wrow[wi];
                a0 += (!(ww ^ x0[wi])).count_ones();
                a1 += (!(ww ^ x1[wi])).count_ones();
                a2 += (!(ww ^ x2[wi])).count_ones();
                a3 += (!(ww ^ x3[wi])).count_ones();
            }
            orow[j] = finish(a0, kw, pad);
            orow[j + 1] = finish(a1, kw, pad);
            orow[j + 2] = finish(a2, kw, pad);
            orow[j + 3] = finish(a3, kw, pad);
            j += 4;
        }
        while j < n {
            orow[j] = finish(popc_xnor_u64(wrow, x.row(j)), kw, pad);
            j += 1;
        }
    }
}

fn gemm_blocked(w: &PackedMatrix, x: &PackedMatrix, out: &mut [i32]) {
    gemm_blocked_rows(w, x, out, 0, w.rows);
}

/// 2x4 register blocking: two w-rows share every loaded x-word (halves
/// x-side loads vs the 1x4 `Blocked`).  §Perf experiment; ablated in
/// benches/ablation.rs.
fn gemm_blocked2x4(w: &PackedMatrix, x: &PackedMatrix, out: &mut [i32]) {
    let (kw, pad) = (w.kw, w.pad_bits());
    let n = x.rows;
    let rows = w.rows;
    let r2 = rows & !1;
    let n4 = n & !3;
    let mut i = 0;
    while i < r2 {
        let w0 = w.row(i);
        let w1 = w.row(i + 1);
        let mut j = 0;
        while j < n4 {
            let x0 = x.row(j);
            let x1 = x.row(j + 1);
            let x2 = x.row(j + 2);
            let x3 = x.row(j + 3);
            let mut acc = [0u32; 8];
            let full2 = kw & !1;
            let mut wi = 0;
            while wi < full2 {
                let wa = (w0[wi] as u64) | ((w0[wi + 1] as u64) << 32);
                let wb = (w1[wi] as u64) | ((w1[wi + 1] as u64) << 32);
                let xa = (x0[wi] as u64) | ((x0[wi + 1] as u64) << 32);
                let xb = (x1[wi] as u64) | ((x1[wi + 1] as u64) << 32);
                let xc = (x2[wi] as u64) | ((x2[wi + 1] as u64) << 32);
                let xd = (x3[wi] as u64) | ((x3[wi + 1] as u64) << 32);
                acc[0] += (!(wa ^ xa)).count_ones();
                acc[1] += (!(wa ^ xb)).count_ones();
                acc[2] += (!(wa ^ xc)).count_ones();
                acc[3] += (!(wa ^ xd)).count_ones();
                acc[4] += (!(wb ^ xa)).count_ones();
                acc[5] += (!(wb ^ xb)).count_ones();
                acc[6] += (!(wb ^ xc)).count_ones();
                acc[7] += (!(wb ^ xd)).count_ones();
                wi += 2;
            }
            if wi < kw {
                for (r, wrow) in [w0, w1].into_iter().enumerate() {
                    let ww = wrow[wi];
                    acc[r * 4] += (!(ww ^ x0[wi])).count_ones();
                    acc[r * 4 + 1] += (!(ww ^ x1[wi])).count_ones();
                    acc[r * 4 + 2] += (!(ww ^ x2[wi])).count_ones();
                    acc[r * 4 + 3] += (!(ww ^ x3[wi])).count_ones();
                }
            }
            for r in 0..2 {
                for c in 0..4 {
                    out[(i + r) * n + j + c] =
                        finish(acc[r * 4 + c], kw, pad);
                }
            }
            j += 4;
        }
        while j < n {
            out[i * n + j] = finish(popc_xnor_u64(w0, x.row(j)), kw, pad);
            out[(i + 1) * n + j] =
                finish(popc_xnor_u64(w1, x.row(j)), kw, pad);
            j += 1;
        }
        i += 2;
    }
    if i < rows {
        // Odd final row: reuse the 1x4 kernel on the tail slice.
        let tail = &mut out[i * n..];
        gemm_blocked_rows(w, x, tail, i, rows);
    }
}

fn gemm_threaded(
    w: &PackedMatrix,
    x: &PackedMatrix,
    out: &mut [i32],
    threads: usize,
) {
    let n = x.rows;
    // Split the output rows into disjoint &mut chunks first, then hand
    // one contiguous row-range to each scoped thread.
    let rows = w.rows;
    let t = threads.max(1).min(rows.max(1));
    let chunk_rows = rows.div_ceil(t);
    let mut slices: Vec<&mut [i32]> = Vec::with_capacity(t);
    let mut rest = out;
    for ti in 0..t {
        let lo = ti * chunk_rows;
        let hi = ((ti + 1) * chunk_rows).min(rows);
        if lo >= hi {
            break;
        }
        let (head, tail) = rest.split_at_mut((hi - lo) * n);
        slices.push(head);
        rest = tail;
    }
    std::thread::scope(|s| {
        for (ti, slice) in slices.into_iter().enumerate() {
            let lo = ti * chunk_rows;
            let hi = ((ti + 1) * chunk_rows).min(rows);
            s.spawn(move || gemm_blocked_rows(w, x, slice, lo, hi));
        }
    });
}

/// Packed gemm dispatch: `out[i * x.rows + j] = <w_i, x_j>` exactly.
///
/// `w`: [D, k] packed, `x`: [N, k] packed (im2col transposed), `out`
/// must have `w.rows * x.rows` elements.
pub fn xnor_gemm(
    w: &PackedMatrix,
    x: &PackedMatrix,
    out: &mut [i32],
    imp: XnorImpl,
) {
    assert_eq!(w.k, x.k, "reduction length mismatch");
    assert_eq!(w.kw, x.kw);
    assert_eq!(out.len(), w.rows * x.rows, "output size");
    match imp {
        XnorImpl::Scalar => gemm_scalar(w, x, out),
        XnorImpl::Word64 => gemm_word64(w, x, out),
        XnorImpl::Blocked => gemm_blocked(w, x, out),
        XnorImpl::Blocked2x4 => gemm_blocked2x4(w, x, out),
        XnorImpl::Threaded(t) => gemm_threaded(w, x, out, t),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::pack::pack_rows;
    use crate::utils::Rng;

    fn dense_dot(a: &[f32], b: &[f32]) -> i32 {
        a.iter().zip(b).map(|(x, y)| (x * y) as i32).sum()
    }

    fn check_all_impls(d: usize, k: usize, n: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let wm = rng.sign_vec(d * k);
        let xm = rng.sign_vec(n * k);
        let w = pack_rows(&wm, d, k);
        let x = pack_rows(&xm, n, k);

        let mut want = vec![0i32; d * n];
        for i in 0..d {
            for j in 0..n {
                want[i * n + j] =
                    dense_dot(&wm[i * k..(i + 1) * k], &xm[j * k..(j + 1) * k]);
            }
        }
        for imp in [
            XnorImpl::Scalar,
            XnorImpl::Word64,
            XnorImpl::Blocked,
            XnorImpl::Blocked2x4,
            XnorImpl::Threaded(3),
        ] {
            let mut got = vec![0i32; d * n];
            xnor_gemm(&w, &x, &mut got, imp);
            assert_eq!(got, want, "impl {:?} d={d} k={k} n={n}", imp);
        }
    }

    #[test]
    fn table1_word_identity() {
        // 2*popcount(~(a^b)) - 32 == dot of the +-1 interpretations.
        let mut rng = Rng::new(0);
        for _ in 0..200 {
            let a = rng.next_u32();
            let b = rng.next_u32();
            let mut dot = 0i32;
            for i in 0..32 {
                let va = if (a >> i) & 1 == 1 { 1 } else { -1 };
                let vb = if (b >> i) & 1 == 1 { 1 } else { -1 };
                dot += va * vb;
            }
            assert_eq!(2 * (!(a ^ b)).count_ones() as i32 - 32, dot);
        }
    }

    #[test]
    fn exact_small_shapes() {
        for (d, k, n) in [(1, 1, 1), (2, 31, 3), (3, 32, 5), (4, 33, 4),
                          (5, 70, 7), (8, 64, 8)] {
            check_all_impls(d, k, n, (d * 1000 + k * 10 + n) as u64);
        }
    }

    #[test]
    fn exact_layer_shape() {
        // A real BNN gemm: conv3 at scale 0.25 (D=64, K=288, N=64).
        check_all_impls(64, 288, 64, 42);
    }

    #[test]
    fn extremes() {
        for k in [1usize, 31, 32, 33, 95] {
            let ones = vec![1.0f32; k];
            let mones = vec![-1.0f32; k];
            let w = pack_rows(&ones, 1, k);
            let xs = pack_rows(&[ones.clone(), mones].concat(), 2, k);
            let mut out = vec![0i32; 2];
            xnor_gemm(&w, &xs, &mut out, XnorImpl::Blocked);
            assert_eq!(out, vec![k as i32, -(k as i32)], "k={k}");
        }
    }

    #[test]
    fn threaded_more_threads_than_rows() {
        check_all_impls(2, 40, 3, 7); // Threaded(3) > 2 rows inside
        let mut rng = Rng::new(9);
        let wm = rng.sign_vec(2 * 40);
        let xm = rng.sign_vec(3 * 40);
        let w = pack_rows(&wm, 2, 40);
        let x = pack_rows(&xm, 3, 40);
        let mut a = vec![0i32; 6];
        let mut b = vec![0i32; 6];
        xnor_gemm(&w, &x, &mut a, XnorImpl::Threaded(64));
        xnor_gemm(&w, &x, &mut b, XnorImpl::Scalar);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "reduction length mismatch")]
    fn rejects_k_mismatch() {
        let w = PackedMatrix::zeros(1, 32);
        let x = PackedMatrix::zeros(1, 64);
        xnor_gemm(&w, &x, &mut [0], XnorImpl::Scalar);
    }

    #[test]
    fn output_parity_and_range() {
        let k = 77;
        let mut rng = Rng::new(5);
        let w = pack_rows(&rng.sign_vec(4 * k), 4, k);
        let x = pack_rows(&rng.sign_vec(6 * k), 6, k);
        let mut out = vec![0i32; 24];
        xnor_gemm(&w, &x, &mut out, XnorImpl::Word64);
        for &v in &out {
            assert!(v.abs() <= k as i32);
            assert_eq!(v.rem_euclid(2), k as i32 % 2);
        }
    }
}
