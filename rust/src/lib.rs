//! BitKernel — an XNOR-bitcount binarized-network inference stack.
//!
//! Reproduction of "A Computing Kernel for Network Binarization on PyTorch"
//! (Xu & Pedersoli, 2019) as a three-layer system:
//!
//! * **L1** Pallas xnor-bitcount / encode kernels (python, build time),
//! * **L2** the Binarized Neural Network forward graph in JAX, AOT-lowered
//!   to HLO text artifacts,
//! * **L3** this crate: a native compute engine (the paper's "CPU" arm),
//!   a PJRT runtime that loads the AOT artifacts (the "accelerator" arm),
//!   and a serving coordinator (dynamic batching, router, metrics, HTTP).
//!
//! Python never runs on the request path: after `make artifacts` the
//! `bitkernel` binary is self-contained.
//!
//! The native engine is COMPILED, not interpreted: `BnnEngine::plan`
//! lowers the network once into a flat op program (kernel dispatch
//! resolved at plan time; binarized layers fuse bn+sign+pack so they
//! emit the next layer's packed bits directly), and `Plan::session`
//! pairs it with preallocated buffers so `Session::run` serves batches
//! with zero steady-state heap allocation.  See `model/plan.rs` and
//! README §"Plan/Session API".
//!
//! Layout:
//! * [`tensor`] — minimal NCHW float tensor + packed bit matrices
//! * [`bitops`] — bit packing and the xnor-bitcount gemm family
//! * [`gemm`]   — float gemm kernels (naive control group / blocked)
//! * [`nn`]     — im2col, conv, pooling, batchnorm, linear, and the
//!   fused `bn_sign_pack` layer-boundary epilogues ([`nn::fuse`])
//! * [`model`]  — the [`model::NetSpec`] architecture IR, BKW1/BKW2
//!   weights, the native engine, and the compiled
//!   [`model::Plan`]/[`model::Session`] execution path
//! * [`data`]   — ShapeSet-10 (BKD1) loading + native generation
//! * [`runtime`] — PJRT client wrapper + artifact manifest/registry
//! * [`coordinator`] — dynamic batcher, replica pool, router, metrics
//! * [`server`] — minimal HTTP/1.1 front-end
//! * [`utils`], [`benchkit`], [`testing`] — substrates built in-repo
//!   (offline environment: no tokio/clap/criterion/proptest)
//!
//! The prose version of this map — request lifecycle, the Plan/Session
//! compile-once contract, the replica pool — lives in
//! `docs/ARCHITECTURE.md`; the operator's guide to the HTTP server is
//! `docs/SERVING.md`.

// Public API documentation is part of the tier-1 bar: `scripts/ci.sh`
// runs `cargo doc --no-deps` with rustdoc warnings denied.
#![warn(missing_docs)]

pub mod benchkit;
pub mod bitops;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod gemm;
pub mod model;
pub mod nn;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod testing;
pub mod utils;
