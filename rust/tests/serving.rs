//! Shape-generic serving tests: one HTTP endpoint over heterogeneous
//! models (different input shapes AND class counts), with replies
//! pinned bit-identical to `forward_reference`, plus randomized-shape
//! submit/body validation (wrong sizes are typed errors / 4xx, never a
//! worker panic).  Everything runs on synthetic engines — no
//! artifacts needed.
//!
//! The adversarial suite at the bottom (slowloris, pipelining,
//! mid-body disconnect) runs against BOTH front ends — the blocking
//! pool and, on linux, the epoll event loop — over real TCP.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bitkernel::bitops::XnorImpl;
use bitkernel::coordinator::{
    Backend, BatcherConfig, MockBackend, NativeBackend, Router,
    RouterConfig, SubmitError,
};
use bitkernel::data::normalize_batch;
use bitkernel::model::{BnnEngine, EngineKernel, NetSpec};
use bitkernel::server::{serve, ServeOptions, HttpRequest, Service};
use bitkernel::testing::synthetic_weight_file;
use bitkernel::utils::json::Json;
use bitkernel::utils::Rng;

const KERNEL: EngineKernel = EngineKernel::Xnor(XnorImpl::Auto);

/// Synthetic engine for `spec`, optionally with a label table riding
/// in the weight file.
fn engine_for(spec: &NetSpec, seed: u64, labels: Option<Vec<String>>)
              -> BnnEngine {
    let mut wf = synthetic_weight_file(spec, seed);
    wf.set_labels(labels);
    BnnEngine::from_weight_file(&wf).expect("synthetic weight file")
}

fn router_for(engine: &BnnEngine, max_batch: usize) -> Router {
    let plan = engine.plan(KERNEL, max_batch).unwrap();
    Router::start(
        move |_replica| {
            Ok(Box::new(NativeBackend::from_plan(&plan))
                as Box<dyn Backend>)
        },
        RouterConfig {
            queue_cap: 64,
            replicas: 2,
            batcher: BatcherConfig {
                max_batch,
                max_delay: Duration::from_millis(2),
            },
            ..RouterConfig::default()
        },
    )
    .unwrap()
}

/// Deterministic fake image bytes for one (c, h, w) model.
fn pixels(c: usize, h: usize, w: usize, salt: usize) -> Vec<u8> {
    (0..c * h * w).map(|i| ((i * 31 + salt * 7) % 256) as u8).collect()
}

#[test]
fn one_endpoint_serves_heterogeneous_models_bit_identical() {
    // Model A: the paper-shaped 3x32x32/10-class conv net, WITH labels.
    let spec_a = NetSpec::builder((3, 32, 32))
        .conv(8, 3)
        .pool()
        .linear(10)
        .build()
        .unwrap();
    let labels_a: Vec<String> =
        (0..10).map(|i| format!("shape-{i}")).collect();
    let engine_a = engine_for(&spec_a, 11, Some(labels_a.clone()));
    // Model B: an fc-heavy 1x28x28/26-class net, label-less.
    let spec_b = NetSpec::builder((1, 28, 28))
        .linear(32)
        .linear(26)
        .build()
        .unwrap();
    let engine_b = engine_for(&spec_b, 22, None);

    let mut routers = BTreeMap::new();
    routers.insert("shapes".to_string(), router_for(&engine_a, 4));
    routers.insert("letters".to_string(), router_for(&engine_b, 4));
    let service = Arc::new(Service::new(routers, "shapes"));

    let stop = Arc::new(AtomicBool::new(false));
    let (ready_tx, ready_rx) = std::sync::mpsc::channel();
    let stop2 = Arc::clone(&stop);
    let svc2 = Arc::clone(&service);
    let server = std::thread::spawn(move || {
        serve(
            svc2,
            &ServeOptions {
                addr: "127.0.0.1:0".into(),
                threads: 2,
                ..ServeOptions::default()
            },
            stop2,
            Some(ready_tx),
        )
        .unwrap();
    });
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).unwrap();

    // /models advertises both shape contracts.
    let (status, models) = http_get(&addr, "/models");
    assert_eq!(status, 200);
    let v = Json::parse(&models).unwrap();
    let arr = v.as_arr().unwrap();
    assert_eq!(arr.len(), 2);
    let by_name = |n: &str| {
        arr.iter()
            .find(|m| m.get("name").unwrap().as_str() == Some(n))
            .unwrap()
    };
    let shapes = by_name("shapes");
    assert_eq!(shapes.get("image_bytes").unwrap().as_usize(),
               Some(3 * 32 * 32));
    assert_eq!(shapes.get("classes").unwrap().as_usize(), Some(10));
    assert_eq!(
        shapes.get("labels").unwrap().as_arr().map(<[Json]>::len),
        Some(10)
    );
    let letters = by_name("letters");
    assert_eq!(letters.get("image_bytes").unwrap().as_usize(),
               Some(28 * 28));
    assert_eq!(letters.get("classes").unwrap().as_usize(), Some(26));
    assert_eq!(letters.get("labels"), Some(&Json::Null));

    // Classify against both, pinning each reply bit-identical to the
    // unfused oracle on the same normalized input.
    for (model, engine, (c, h, w), labels) in [
        ("shapes", &engine_a, (3usize, 32usize, 32usize),
         Some(&labels_a)),
        ("letters", &engine_b, (1, 28, 28), None),
    ] {
        for salt in 0..3 {
            let px = pixels(c, h, w, salt);
            let x = normalize_batch(&px, 1, h, w, c);
            let reference = engine.forward_reference(&x, KERNEL);
            let (status, body) =
                http_post(&addr, &format!("/classify?model={model}"), &px);
            assert_eq!(status, 200, "{model}: {body}");
            let v = Json::parse(&body).unwrap();
            assert_eq!(v.get("model").unwrap().as_str(), Some(model));
            let logits: Vec<f32> = v
                .get("logits")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|j| j.as_f64().unwrap() as f32)
                .collect();
            assert_eq!(logits.len(), reference.dim(1));
            for (i, (&got, &want)) in
                logits.iter().zip(reference.data()).enumerate()
            {
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "{model} salt {salt} logit {i}: {got} vs {want} — \
                     the HTTP path must be bit-identical to \
                     forward_reference"
                );
            }
            let class = v.get("class").unwrap().as_usize().unwrap();
            let expect_label = match labels {
                Some(l) => l[class].clone(),
                None => class.to_string(),
            };
            assert_eq!(v.get("label").unwrap().as_str(),
                       Some(expect_label.as_str()));
        }
    }

    // Wrong-size bodies are 400s naming the expected count; the wrong
    // model's byte count never reaches a worker.
    let (status, body) =
        http_post(&addr, "/classify?model=letters", &pixels(3, 32, 32, 0));
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("784"), "{body}");

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
}

#[test]
fn randomized_shapes_validate_submits_and_bodies() {
    let mut rng = Rng::new(99);
    for case in 0..8usize {
        let c = 1 + rng.below(4);
        let h = 3 + rng.below(14);
        let w = 3 + rng.below(14);
        let classes = 2 + rng.below(30);
        let router = Router::start(
            move |_| {
                Ok(Box::new(MockBackend::with_shape(
                    4, 0, (c, h, w), classes,
                )) as Box<dyn Backend>)
            },
            RouterConfig {
                queue_cap: 16,
                replicas: 1,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_delay: Duration::from_millis(1),
                },
                ..RouterConfig::default()
            },
        )
        .unwrap();
        let elems = c * h * w;
        assert_eq!(router.image_elems(), elems, "case {case}");

        // Wrong-size submits are typed errors at admission...
        for bad in [0usize, elems - 1, elems + 1, elems * 2] {
            assert_eq!(
                router.submit(vec![0.0; bad]).err(),
                Some(SubmitError::WrongShape { expected: elems, got: bad }),
                "case {case} ({c}x{h}x{w}), bad len {bad}"
            );
        }
        // ... and a correct submit afterwards still round-trips (no
        // worker saw — let alone panicked on — the malformed ones).
        let reply = router.submit_wait(vec![0.1; elems]).unwrap();
        assert_eq!(reply.logits.len(), classes, "case {case}");

        // Same contract at the HTTP layer: wrong byte counts are 400s.
        let mut routers = BTreeMap::new();
        routers.insert("m".to_string(), router);
        let svc = Service::new(routers, "m");
        let post = |body: Vec<u8>| HttpRequest {
            method: "POST".into(),
            path: "/classify".into(),
            query: BTreeMap::new(),
            headers: BTreeMap::new(),
            body,
            version: "HTTP/1.1".into(),
        };
        assert_eq!(svc.handle(post(vec![7u8; elems + 1])).status, 400,
                   "case {case}");
        assert_eq!(svc.handle(post(vec![7u8; elems])).status, 200,
                   "case {case}");
    }
}

// --- adversarial clients, against both front ends --------------------------

/// Front ends worth running an adversarial client against: the
/// blocking pool everywhere, plus the epoll event loop on linux.
fn front_ends() -> Vec<bool> {
    if cfg!(target_os = "linux") {
        vec![false, true]
    } else {
        vec![false]
    }
}

/// Spawn a mock-backed server (3x32x32/10 model "m", default) with
/// the chosen front end and idle timeout.  Returns the bound address,
/// the stop flag, the server join handle, and the service (for
/// metrics assertions).
fn spawn_mock_server(
    event_loop: bool,
    idle_ms: u64,
) -> (
    std::net::SocketAddr,
    Arc<AtomicBool>,
    std::thread::JoinHandle<()>,
    Arc<Service>,
) {
    let mut routers = BTreeMap::new();
    routers.insert(
        "m".to_string(),
        Router::start(
            |_| Ok(Box::new(MockBackend::new(8, 0)) as Box<dyn Backend>),
            RouterConfig { replicas: 2, ..RouterConfig::default() },
        )
        .unwrap(),
    );
    let service = Arc::new(Service::new(routers, "m"));
    let stop = Arc::new(AtomicBool::new(false));
    let (ready_tx, ready_rx) = std::sync::mpsc::channel();
    let stop2 = Arc::clone(&stop);
    let svc2 = Arc::clone(&service);
    let server = std::thread::spawn(move || {
        serve(
            svc2,
            &ServeOptions {
                addr: "127.0.0.1:0".into(),
                threads: 2,
                idle_timeout: Duration::from_millis(idle_ms),
                event_loop,
                io_threads: 2,
                ..ServeOptions::default()
            },
            stop2,
            Some(ready_tx),
        )
        .unwrap();
    });
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).unwrap();
    (addr, stop, server, service)
}

/// Read until the server closes the connection (returning whatever it
/// sent first, e.g. a best-effort 400).  Panics if the socket is
/// still open after ~5 s.
fn read_until_close(stream: &TcpStream) -> Vec<u8> {
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    let mut got = Vec::new();
    let mut buf = [0u8; 1024];
    loop {
        match (&*stream).read(&mut buf) {
            Ok(0) => return got,
            Ok(n) => got.extend_from_slice(&buf[..n]),
            Err(e) => panic!(
                "server kept the connection open past the idle \
                 timeout: {e} (read so far: {} bytes)",
                got.len()
            ),
        }
    }
}

#[test]
fn slowloris_header_trickle_is_closed_and_pool_stays_healthy() {
    for event_loop in front_ends() {
        let (addr, stop, server, _svc) =
            spawn_mock_server(event_loop, 200);
        // Three trickling peers in parallel: each sends a partial
        // header line and then goes quiet past the idle timeout.
        let streams: Vec<TcpStream> = (0..3)
            .map(|_| {
                let mut s = TcpStream::connect(addr).unwrap();
                s.write_all(b"GET /healthz HTTP/1.1\r\nHost: tr")
                    .unwrap();
                s
            })
            .collect();
        for s in &streams {
            // The server must hang up on its own (no bytes were ever
            // a complete request, so no response is required —
            // the blocking path may send a best-effort 400).
            let _ = read_until_close(s);
        }
        // The pool was never occupied by the tricklers: a well-formed
        // request still answers instantly.
        let (status, _) = http_get(&addr, "/healthz");
        assert_eq!(status, 200, "event_loop={event_loop}");
        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }
}

#[test]
fn pipelined_requests_answer_in_order_on_one_connection() {
    for event_loop in front_ends() {
        let (addr, stop, server, svc) =
            spawn_mock_server(event_loop, 5_000);
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        // Two classifies and a healthz, written back-to-back before
        // reading anything.
        let body = vec![7u8; 3 * 32 * 32];
        let mut burst = Vec::new();
        for _ in 0..2 {
            burst.extend_from_slice(
                format!(
                    "POST /classify HTTP/1.1\r\nHost: t\r\n\
                     Content-Length: {}\r\n\r\n",
                    body.len()
                )
                .as_bytes(),
            );
            burst.extend_from_slice(&body);
        }
        burst.extend_from_slice(
            b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n",
        );
        stream.write_all(&burst).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        for i in 0..2 {
            let (status, body) = read_one_response(&mut reader);
            assert_eq!(status, 200, "resp {i}: {body}");
            let v = Json::parse(&body).unwrap();
            assert_eq!(v.get("model").unwrap().as_str(), Some("m"),
                       "event_loop={event_loop}");
        }
        let (status, body) = read_one_response(&mut reader);
        assert_eq!(status, 200);
        assert_eq!(body, "ok\n");
        // All three rode one connection: that is two keep-alive
        // reuses on the server's counter.
        assert!(
            svc.http_metrics()
                .keepalive_reuses
                .load(std::sync::atomic::Ordering::Relaxed)
                >= 2,
            "event_loop={event_loop}"
        );
        drop(reader);
        drop(stream);
        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }
}

#[test]
fn mid_body_disconnect_never_wedges_a_replica() {
    for event_loop in front_ends() {
        let (addr, stop, server, _svc) =
            spawn_mock_server(event_loop, 5_000);
        // Several clients advertise a full body, send half, vanish.
        for _ in 0..4 {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(
                format!(
                    "POST /classify HTTP/1.1\r\nHost: t\r\n\
                     Content-Length: {}\r\n\r\n",
                    3 * 32 * 32
                )
                .as_bytes(),
            )
            .unwrap();
            let torso = vec![1u8; 3 * 32 * 32 / 2];
            s.write_all(&torso).unwrap();
            drop(s); // RST/FIN mid-body
        }
        // No replica ever saw those torsos; a real request with a
        // bounded deadline still answers 200 (not 504, not a hang).
        let img = vec![9u8; 3 * 32 * 32];
        let (status, body) =
            http_post(&addr, "/classify?timeout_ms=5000", &img);
        assert_eq!(status, 200, "event_loop={event_loop}: {body}");
        stop.store(true, Ordering::Relaxed);
        server.join().unwrap();
    }
}

// --- tiny test HTTP client -------------------------------------------------

fn http_get(addr: &std::net::SocketAddr, path: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(stream,
           "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .unwrap();
    read_response(stream)
}

fn http_post(addr: &std::net::SocketAddr, path: &str, body: &[u8])
             -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )
    .unwrap();
    stream.write_all(body).unwrap();
    read_response(stream)
}

fn read_response(stream: TcpStream) -> (u16, String) {
    read_one_response(&mut BufReader::new(stream))
}

/// Read exactly one framed response without consuming past its body,
/// so the same reader can pull further pipelined/keep-alive replies.
fn read_one_response(
    reader: &mut BufReader<TcpStream>,
) -> (u16, String) {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 =
        status_line.split(' ').nth(1).unwrap().parse().unwrap();
    let mut len = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line.to_lowercase().strip_prefix("content-length:")
        {
            len = v.trim().parse().unwrap();
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}
