//! Shape-generic serving tests: one HTTP endpoint over heterogeneous
//! models (different input shapes AND class counts), with replies
//! pinned bit-identical to `forward_reference`, plus randomized-shape
//! submit/body validation (wrong sizes are typed errors / 4xx, never a
//! worker panic).  Everything runs on synthetic engines — no
//! artifacts needed.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bitkernel::bitops::XnorImpl;
use bitkernel::coordinator::{
    Backend, BatcherConfig, MockBackend, NativeBackend, Router,
    RouterConfig, SubmitError,
};
use bitkernel::data::normalize_batch;
use bitkernel::model::{BnnEngine, EngineKernel, NetSpec};
use bitkernel::server::{serve, ServeOptions, HttpRequest, Service};
use bitkernel::testing::synthetic_weight_file;
use bitkernel::utils::json::Json;
use bitkernel::utils::Rng;

const KERNEL: EngineKernel = EngineKernel::Xnor(XnorImpl::Auto);

/// Synthetic engine for `spec`, optionally with a label table riding
/// in the weight file.
fn engine_for(spec: &NetSpec, seed: u64, labels: Option<Vec<String>>)
              -> BnnEngine {
    let mut wf = synthetic_weight_file(spec, seed);
    wf.set_labels(labels);
    BnnEngine::from_weight_file(&wf).expect("synthetic weight file")
}

fn router_for(engine: &BnnEngine, max_batch: usize) -> Router {
    let plan = engine.plan(KERNEL, max_batch).unwrap();
    Router::start(
        move |_replica| {
            Ok(Box::new(NativeBackend::from_plan(&plan))
                as Box<dyn Backend>)
        },
        RouterConfig {
            queue_cap: 64,
            replicas: 2,
            batcher: BatcherConfig {
                max_batch,
                max_delay: Duration::from_millis(2),
            },
        },
    )
    .unwrap()
}

/// Deterministic fake image bytes for one (c, h, w) model.
fn pixels(c: usize, h: usize, w: usize, salt: usize) -> Vec<u8> {
    (0..c * h * w).map(|i| ((i * 31 + salt * 7) % 256) as u8).collect()
}

#[test]
fn one_endpoint_serves_heterogeneous_models_bit_identical() {
    // Model A: the paper-shaped 3x32x32/10-class conv net, WITH labels.
    let spec_a = NetSpec::builder((3, 32, 32))
        .conv(8, 3)
        .pool()
        .linear(10)
        .build()
        .unwrap();
    let labels_a: Vec<String> =
        (0..10).map(|i| format!("shape-{i}")).collect();
    let engine_a = engine_for(&spec_a, 11, Some(labels_a.clone()));
    // Model B: an fc-heavy 1x28x28/26-class net, label-less.
    let spec_b = NetSpec::builder((1, 28, 28))
        .linear(32)
        .linear(26)
        .build()
        .unwrap();
    let engine_b = engine_for(&spec_b, 22, None);

    let mut routers = BTreeMap::new();
    routers.insert("shapes".to_string(), router_for(&engine_a, 4));
    routers.insert("letters".to_string(), router_for(&engine_b, 4));
    let service = Arc::new(Service::new(routers, "shapes"));

    let stop = Arc::new(AtomicBool::new(false));
    let (ready_tx, ready_rx) = std::sync::mpsc::channel();
    let stop2 = Arc::clone(&stop);
    let svc2 = Arc::clone(&service);
    let server = std::thread::spawn(move || {
        serve(
            svc2,
            &ServeOptions {
                addr: "127.0.0.1:0".into(),
                threads: 2,
                ..ServeOptions::default()
            },
            stop2,
            Some(ready_tx),
        )
        .unwrap();
    });
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).unwrap();

    // /models advertises both shape contracts.
    let (status, models) = http_get(&addr, "/models");
    assert_eq!(status, 200);
    let v = Json::parse(&models).unwrap();
    let arr = v.as_arr().unwrap();
    assert_eq!(arr.len(), 2);
    let by_name = |n: &str| {
        arr.iter()
            .find(|m| m.get("name").unwrap().as_str() == Some(n))
            .unwrap()
    };
    let shapes = by_name("shapes");
    assert_eq!(shapes.get("image_bytes").unwrap().as_usize(),
               Some(3 * 32 * 32));
    assert_eq!(shapes.get("classes").unwrap().as_usize(), Some(10));
    assert_eq!(
        shapes.get("labels").unwrap().as_arr().map(<[Json]>::len),
        Some(10)
    );
    let letters = by_name("letters");
    assert_eq!(letters.get("image_bytes").unwrap().as_usize(),
               Some(28 * 28));
    assert_eq!(letters.get("classes").unwrap().as_usize(), Some(26));
    assert_eq!(letters.get("labels"), Some(&Json::Null));

    // Classify against both, pinning each reply bit-identical to the
    // unfused oracle on the same normalized input.
    for (model, engine, (c, h, w), labels) in [
        ("shapes", &engine_a, (3usize, 32usize, 32usize),
         Some(&labels_a)),
        ("letters", &engine_b, (1, 28, 28), None),
    ] {
        for salt in 0..3 {
            let px = pixels(c, h, w, salt);
            let x = normalize_batch(&px, 1, h, w, c);
            let reference = engine.forward_reference(&x, KERNEL);
            let (status, body) =
                http_post(&addr, &format!("/classify?model={model}"), &px);
            assert_eq!(status, 200, "{model}: {body}");
            let v = Json::parse(&body).unwrap();
            assert_eq!(v.get("model").unwrap().as_str(), Some(model));
            let logits: Vec<f32> = v
                .get("logits")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|j| j.as_f64().unwrap() as f32)
                .collect();
            assert_eq!(logits.len(), reference.dim(1));
            for (i, (&got, &want)) in
                logits.iter().zip(reference.data()).enumerate()
            {
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "{model} salt {salt} logit {i}: {got} vs {want} — \
                     the HTTP path must be bit-identical to \
                     forward_reference"
                );
            }
            let class = v.get("class").unwrap().as_usize().unwrap();
            let expect_label = match labels {
                Some(l) => l[class].clone(),
                None => class.to_string(),
            };
            assert_eq!(v.get("label").unwrap().as_str(),
                       Some(expect_label.as_str()));
        }
    }

    // Wrong-size bodies are 400s naming the expected count; the wrong
    // model's byte count never reaches a worker.
    let (status, body) =
        http_post(&addr, "/classify?model=letters", &pixels(3, 32, 32, 0));
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("784"), "{body}");

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
}

#[test]
fn randomized_shapes_validate_submits_and_bodies() {
    let mut rng = Rng::new(99);
    for case in 0..8usize {
        let c = 1 + rng.below(4);
        let h = 3 + rng.below(14);
        let w = 3 + rng.below(14);
        let classes = 2 + rng.below(30);
        let router = Router::start(
            move |_| {
                Ok(Box::new(MockBackend::with_shape(
                    4, 0, (c, h, w), classes,
                )) as Box<dyn Backend>)
            },
            RouterConfig {
                queue_cap: 16,
                replicas: 1,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_delay: Duration::from_millis(1),
                },
            },
        )
        .unwrap();
        let elems = c * h * w;
        assert_eq!(router.image_elems(), elems, "case {case}");

        // Wrong-size submits are typed errors at admission...
        for bad in [0usize, elems - 1, elems + 1, elems * 2] {
            assert_eq!(
                router.submit(vec![0.0; bad]).err(),
                Some(SubmitError::WrongShape { expected: elems, got: bad }),
                "case {case} ({c}x{h}x{w}), bad len {bad}"
            );
        }
        // ... and a correct submit afterwards still round-trips (no
        // worker saw — let alone panicked on — the malformed ones).
        let reply = router.submit_wait(vec![0.1; elems]).unwrap();
        assert_eq!(reply.logits.len(), classes, "case {case}");

        // Same contract at the HTTP layer: wrong byte counts are 400s.
        let mut routers = BTreeMap::new();
        routers.insert("m".to_string(), router);
        let svc = Service::new(routers, "m");
        let post = |body: Vec<u8>| HttpRequest {
            method: "POST".into(),
            path: "/classify".into(),
            query: BTreeMap::new(),
            headers: BTreeMap::new(),
            body,
            version: "HTTP/1.1".into(),
        };
        assert_eq!(svc.handle(post(vec![7u8; elems + 1])).status, 400,
                   "case {case}");
        assert_eq!(svc.handle(post(vec![7u8; elems])).status, 200,
                   "case {case}");
    }
}

// --- tiny test HTTP client -------------------------------------------------

fn http_get(addr: &std::net::SocketAddr, path: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(stream,
           "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .unwrap();
    read_response(stream)
}

fn http_post(addr: &std::net::SocketAddr, path: &str, body: &[u8])
             -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )
    .unwrap();
    stream.write_all(body).unwrap();
    read_response(stream)
}

fn read_response(stream: TcpStream) -> (u16, String) {
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 =
        status_line.split(' ').nth(1).unwrap().parse().unwrap();
    let mut len = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line.to_lowercase().strip_prefix("content-length:")
        {
            len = v.trim().parse().unwrap();
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}
