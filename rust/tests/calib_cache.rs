//! End-to-end test of the persistent calibration cache: with
//! `BITKERNEL_CALIBRATE` on, the FIRST plan build of each gemm shape
//! microbenches, and every subsequent build — a second `plan()` of the
//! same model, or a registry reload (`PUT /models/{name}`) rebuilding
//! its pipeline — answers from the cache with ZERO microbenches, as
//! counted by `bitkernel_calibrations_total`.
//!
//! This binary holds exactly ONE test because it configures the
//! process-global cache through the environment (`calib::global()`
//! reads the env once, at first use); unit-level coverage that needs
//! no env lives in `model/calib.rs` against explicit instances.

use std::time::Duration;

use bitkernel::bitops::XnorImpl;
use bitkernel::model::{calib, CalibCache, EngineKernel};
use bitkernel::server::{ModelRegistry, RegistryConfig};
use bitkernel::testing::{synthetic_engine, synthetic_weight_file};

#[test]
fn warm_cache_makes_repeat_plan_builds_and_reloads_bench_free() {
    let dir = std::env::temp_dir()
        .join(format!("bk-calib-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cache_path = dir.join("calib");
    // Must happen before anything touches calib::global(): the global
    // cache reads its configuration from the env exactly once.
    std::env::set_var("BITKERNEL_CALIBRATE", "1");
    std::env::set_var("BITKERNEL_CALIB_CACHE", &cache_path);

    // --- Plan-level: second build of the same engine is bench-free.
    let engine = synthetic_engine([4, 4, 6, 6, 8, 8, 16, 12, 10], 21);
    let kernel = EngineKernel::Xnor(XnorImpl::Auto);
    let t0 = calib::calibrations_total();
    let plan1 = engine.plan(kernel, 2).unwrap();
    let t1 = calib::calibrations_total();
    assert!(t1 > t0, "first build must microbench its gemm shapes");
    let plan2 = engine.plan(kernel, 2).unwrap();
    assert_eq!(calib::calibrations_total(), t1,
               "rebuilding an identical plan must run zero microbenches");
    // Cached winners are the winners: both plans picked identically.
    assert_eq!(plan1.xnor_impls(), plan2.xnor_impls());
    for imp in plan1.xnor_impls() {
        assert_ne!(imp, XnorImpl::Auto, "unresolved Auto op");
    }

    // --- Registry-level: a reload rebuilds the pipeline through the
    // same plan path and must hit the cache (satellite of PR 10: hot
    // reloads stop paying the microbench).  Different widths than
    // above so the mount itself proves cold shapes still bench.
    let spec = bitkernel::model::NetSpec::from_widths(
        &[4, 6, 4, 6, 4, 4, 12, 10, 10],
    )
    .unwrap();
    let bkw = dir.join("model.bkw");
    synthetic_weight_file(&spec, 7).save(&bkw).unwrap();
    let registry = ModelRegistry::new(RegistryConfig {
        kernel,
        max_batch: 2,
        ..RegistryConfig::default()
    });
    let entry = registry.mount("m", &bkw, false).unwrap();
    assert_eq!(
        entry.wait_settled(Duration::from_secs(30)).error, None
    );
    let after_mount = calib::calibrations_total();
    assert!(after_mount > t1, "cold mount shapes must microbench");
    let entry = registry.reload("m").unwrap();
    let status = entry.wait_settled(Duration::from_secs(30));
    assert_eq!(status.error, None);
    assert!(status.generation >= 2, "{status:?}");
    assert_eq!(calib::calibrations_total(), after_mount,
               "reload rebuilt the plan without a single microbench");

    // --- Persistence: the sidecar holds every calibrated shape, and a
    // fresh instance over it (what a NEW process would open) is warm.
    let warm = CalibCache::open(Some(cache_path.clone()));
    assert_eq!(warm.len() as u64, after_mount - t0,
               "every microbenched shape must have been persisted");

    std::fs::remove_dir_all(&dir).ok();
}
