//! PJRT-runtime integration: the AOT artifacts must load, execute, and
//! agree with the native engine (cross-LANGUAGE, cross-RUNTIME check:
//! jax/pallas-lowered HLO vs hand-written rust kernels).
//!
//! Whole crate gated on the `pjrt` feature: without it the runtime is
//! the error-returning stub and these tests have nothing to exercise.
#![cfg(feature = "pjrt")]

use bitkernel::bitops::XnorImpl;
use bitkernel::data::Dataset;
use bitkernel::model::{BnnEngine, EngineKernel};
use bitkernel::runtime::Runtime;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn manifest_enumerates_models_and_kernels() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    assert!(rt.manifest.models.len() >= 9, "{}", rt.manifest.models.len());
    assert!(rt.manifest.kernels.len() >= 3);
    for variant in ["xnor", "control", "optimized"] {
        assert!(rt.manifest.find_model("small", variant, 1).is_ok());
    }
}

#[test]
fn pjrt_arms_agree_with_native_engine() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let engine = BnnEngine::load(dir.join("weights_small.bkw")).unwrap();
    let ds = Dataset::load(dir.join("dataset_test.bin")).unwrap();
    let x = ds.normalized(0, 1);
    let native = engine.forward(&x, EngineKernel::Xnor(XnorImpl::Blocked));

    for variant in ["optimized", "xnor", "control"] {
        let model = rt.load_by("small", variant, 1).unwrap();
        let out = model.infer(&x).unwrap();
        assert_eq!(out.shape(), &[1, 10]);
        let diff = out.max_abs_diff(&native);
        assert!(diff <= 5e-3, "pjrt {variant} vs native: {diff}");
    }
}

#[test]
fn pjrt_batch8_matches_batch1() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let ds = Dataset::load(dir.join("dataset_test.bin")).unwrap();
    let xb = ds.normalized(0, 8);
    let batched = rt.load_by("small", "xnor", 8).unwrap().infer(&xb).unwrap();
    let m1_name = rt.manifest.find_model("small", "xnor", 1).unwrap().name.clone();
    let m1 = rt.load_model(&m1_name).unwrap();
    for i in 0..8 {
        let single = m1.infer(&ds.normalized(i, i + 1)).unwrap();
        for c in 0..10 {
            let d = (single.row(0)[c] - batched.row(i)[c]).abs();
            assert!(d <= 1e-4, "img {i} class {c}: {d}");
        }
    }
}

#[test]
fn pjrt_predictions_match_labels() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let ds = Dataset::load(dir.join("dataset_test.bin")).unwrap();
    let n = 32;
    let model = rt.load_by("small", "xnor", 8).unwrap();
    let mut correct = 0;
    for chunk in 0..n / 8 {
        let x = ds.normalized(chunk * 8, (chunk + 1) * 8);
        let logits = model.infer(&x).unwrap();
        for i in 0..8 {
            let pred = bitkernel::nn::argmax(logits.row(i));
            if pred == ds.labels[chunk * 8 + i] as usize {
                correct += 1;
            }
        }
    }
    assert!(correct as f32 / n as f32 >= 0.9, "{correct}/{n}");
}

#[test]
fn kernel_micro_executables_run() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).unwrap();
    // The optimized f32 kernel at conv2 shape: matmul of ones -> K.
    let entry = rt
        .manifest
        .kernels
        .iter()
        .find(|k| k.kernel == "optimized" && k.tag == "conv2")
        .unwrap()
        .clone();
    let exe = rt.load_kernel(&entry.name).unwrap();
    let a = xla::Literal::vec1(&vec![1.0f32; entry.d * entry.k])
        .reshape(&[entry.d as i64, entry.k as i64])
        .unwrap();
    let b = xla::Literal::vec1(&vec![1.0f32; entry.k * entry.n])
        .reshape(&[entry.k as i64, entry.n as i64])
        .unwrap();
    let out = exe.execute::<xla::Literal>(&[a, b]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap()
        .to_tuple1()
        .unwrap();
    let vals = out.to_vec::<f32>().unwrap();
    assert_eq!(vals.len(), entry.d * entry.n);
    assert!(vals.iter().all(|&v| v == entry.k as f32));
}
