//! Plan/session execution tests against a synthetic engine — these run
//! WITHOUT artifacts, unlike the integration tests, so the compiled hot
//! path is covered in every environment.
//!
//! Pins the three plan/session contracts:
//! 1. `Session::run` is BIT-IDENTICAL to the legacy unfused pipeline
//!    (`BnnEngine::forward_reference`) on every Table-2 arm and at odd
//!    batch sizes — the fused encode/bn_sign_pack ops change data
//!    movement, never arithmetic.
//! 2. A session carries no state between runs (buffer reuse is safe).
//! 3. Steady-state runs never reallocate any session buffer.

use std::cell::RefCell;

use bitkernel::bitops::XnorImpl;
use bitkernel::model::EngineKernel;
use bitkernel::nn::argmax;
use bitkernel::tensor::Tensor;
use bitkernel::testing::{prop_assert, synthetic_engine};
use bitkernel::utils::Rng;

/// Small-but-complete architecture: float conv1, binarized convs with
/// all three pools, three fcs.  widths[4] == widths[5] as the BNN
/// topology requires.
const WIDTHS: [u32; 9] = [4, 4, 6, 6, 8, 8, 16, 12, 10];
const CHW: usize = 3 * 32 * 32;
const MAX_BATCH: usize = 4;

fn arms() -> [EngineKernel; 9] {
    [
        EngineKernel::Xnor(XnorImpl::Scalar),
        EngineKernel::Xnor(XnorImpl::Blocked),
        EngineKernel::Xnor(XnorImpl::Wide),
        EngineKernel::Xnor(XnorImpl::Simd),
        // Detection-gated: real 512-bit tiles on AVX-512 hosts, the
        // avx2/wide fallback elsewhere — bit-identical either way.
        EngineKernel::Xnor(XnorImpl::Avx512),
        EngineKernel::Xnor(XnorImpl::Threaded(2)),
        EngineKernel::Xnor(XnorImpl::Auto),
        EngineKernel::Control,
        EngineKernel::Optimized,
    ]
}

fn images(rng: &mut Rng, b: usize) -> Tensor {
    Tensor::new(vec![b, 3, 32, 32], rng.normal_vec(b * CHW))
}

#[test]
fn prop_session_bit_identical_to_legacy_forward() {
    let engine = synthetic_engine(WIDTHS, 71);
    for kernel in arms() {
        let session =
            RefCell::new(engine.plan(kernel, MAX_BATCH).unwrap().session());
        prop_assert(72, 9, |rng, case| {
            // Odd batch sizes on purpose: 1, 3, and max_batch.
            let b = [1, 3, MAX_BATCH][case % 3];
            let x = images(rng, b);
            let want = engine.forward_reference(&x, kernel);
            let mut s = session.borrow_mut();
            let got = s.run(&x);
            if got.shape() != want.shape() {
                return Err(format!(
                    "{kernel:?} b={b}: shape {:?} vs {:?}",
                    got.shape(),
                    want.shape()
                ));
            }
            let diff = got.max_abs_diff(&want);
            if diff != 0.0 {
                return Err(format!(
                    "{kernel:?} b={b}: max |Δlogit| = {diff} (must be \
                     bit-identical)"
                ));
            }
            Ok(())
        });
    }
}

#[test]
fn consecutive_runs_do_not_contaminate() {
    let engine = synthetic_engine(WIDTHS, 73);
    for kernel in [
        EngineKernel::Xnor(XnorImpl::Blocked),
        EngineKernel::Control,
        EngineKernel::Optimized,
    ] {
        let mut session = engine.plan(kernel, MAX_BATCH).unwrap().session();
        let mut rng = Rng::new(9001);
        let x1 = images(&mut rng, MAX_BATCH);
        let x2 = images(&mut rng, 2);
        let first = session.run(&x1).clone();
        let mid = session.run(&x2).clone(); // smaller batch in between
        let again = session.run(&x1).clone();
        assert_eq!(first, again, "{kernel:?}: state leaked across runs");
        // The interleaved small batch matches a fresh session too.
        let fresh = engine.plan(kernel, MAX_BATCH).unwrap().session()
            .run(&x2)
            .clone();
        assert_eq!(mid, fresh, "{kernel:?}: stale buffer contents leaked");
    }
}

#[test]
fn batch_rows_match_single_image_runs() {
    let engine = synthetic_engine(WIDTHS, 77);
    let mut rng = Rng::new(5);
    let x = images(&mut rng, 3);
    let kernel = EngineKernel::Xnor(XnorImpl::Blocked);
    let mut session = engine.plan(kernel, 3).unwrap().session();
    let batch = session.run(&x).clone();
    let chw = CHW;
    for i in 0..3 {
        let single = Tensor::new(vec![1, 3, 32, 32],
                                 x.data()[i * chw..(i + 1) * chw].to_vec());
        let row = session.run(&single).clone();
        assert_eq!(row.row(0), batch.row(i), "image {i}");
    }
}

#[test]
fn steady_state_runs_never_reallocate() {
    let engine = synthetic_engine(WIDTHS, 74);
    for kernel in arms() {
        let mut session = engine.plan(kernel, MAX_BATCH).unwrap().session();
        let mut rng = Rng::new(4242);
        // Every buffer is preallocated at session creation: even the
        // FIRST run must leave the allocation fingerprint untouched.
        let sig = session.buffer_signature();
        for case in 0..8 {
            let b = [MAX_BATCH, 1, 2, 3][case % 4];
            let x = images(&mut rng, b);
            let _ = session.run(&x);
            assert_eq!(session.buffer_signature(), sig,
                       "{kernel:?}: buffer reallocated (case {case}, b={b})");
        }
    }
}

#[test]
fn wrappers_are_thin_shims_over_the_plan() {
    let engine = synthetic_engine(WIDTHS, 75);
    let mut rng = Rng::new(7);
    let x = images(&mut rng, 3);
    let kernel = EngineKernel::Xnor(XnorImpl::Blocked);
    let want = engine.forward_reference(&x, kernel);

    assert_eq!(engine.forward(&x, kernel), want);

    let preds = engine.predict(&x, kernel);
    for (i, p) in preds.iter().enumerate() {
        assert_eq!(*p, argmax(want.row(i)), "image {i}");
    }

    let (out, stages) = engine.forward_profiled(&x, kernel);
    assert_eq!(out, want);
    assert_eq!(stages.len(), engine.plan(kernel, 3).unwrap().num_ops());
}

#[test]
fn fused_epilogue_is_a_distinct_profiling_stage() {
    let engine = synthetic_engine(WIDTHS, 78);
    let xnor = engine.plan(EngineKernel::Xnor(XnorImpl::Blocked), 2).unwrap();
    let names = xnor.stage_names();
    for needle in ["conv1:im2col", "conv2:encode", "pool2",
                   "flatten:bn_sign_pack", "fc1:xnor-gemm",
                   "fc1:bn_sign_pack", "fc3:bn+logits"] {
        assert!(names.iter().any(|n| n == needle),
                "xnor plan missing stage {needle}: {names:?}");
    }
    // The xnor arm never materializes a bn'd float activation: no
    // standalone bn op anywhere in its program.
    assert!(!names.iter().any(|n| n.ends_with(":bn")), "{names:?}");

    let control = engine.plan(EngineKernel::Control, 2).unwrap();
    let names = control.stage_names();
    for needle in ["conv1:bn", "conv2:im2col+sign", "flatten",
                   "fc1:sign", "fc3:bn+logits"] {
        assert!(names.iter().any(|n| n == needle),
                "control plan missing stage {needle}: {names:?}");
    }

    // And the profiled run reports exactly the compiled stages.
    let mut rng = Rng::new(12);
    let x = images(&mut rng, 2);
    let mut session = xnor.session();
    let (_, stages) = session.run_profiled(&x);
    let got: Vec<&str> = stages.iter().map(|(n, _)| n.as_str()).collect();
    let want: Vec<&str> =
        xnor.stage_names().iter().map(|n| n.as_str()).collect();
    assert_eq!(got, want);
}

#[test]
fn auto_plan_resolves_impls_and_stays_bit_identical() {
    let engine = synthetic_engine(WIDTHS, 79);
    let kernel = EngineKernel::Xnor(XnorImpl::Auto);
    let plan = engine.plan(kernel, MAX_BATCH).unwrap();

    // Every xnor op resolved to a concrete impl at plan time...
    let impls = plan.xnor_impls();
    assert!(!impls.is_empty());
    for imp in &impls {
        assert!(!matches!(imp, XnorImpl::Auto), "unresolved Auto op");
    }
    // ...and the chosen impl is recorded in the stage name.
    let gemm_names: Vec<&String> = plan
        .stage_names()
        .iter()
        .filter(|n| n.contains(":xnor-gemm"))
        .collect();
    assert_eq!(gemm_names.len(), impls.len());
    for (name, imp) in gemm_names.iter().zip(&impls) {
        assert!(name.ends_with(&format!("[{}]", imp.name())),
                "stage {name} does not record {imp:?}");
    }

    // On AVX-512 hosts the small gemm shapes of this synthetic net
    // must resolve Auto to the new 512-bit arm (big shapes may pick
    // Threaded), and the stage name records it; elsewhere the
    // single-core pick is Simd.  Either way the name round-trips
    // through from_name — the contract the calibration cache's
    // sidecar format rests on.
    let single = if bitkernel::bitops::avx512_available() {
        XnorImpl::Avx512
    } else {
        XnorImpl::Simd
    };
    for imp in &impls {
        assert!(
            matches!(imp, XnorImpl::Threaded(_)) || *imp == single,
            "Auto resolved {imp:?}, expected {single:?} or Threaded"
        );
        assert_eq!(XnorImpl::from_name(&imp.name()), Some(*imp));
    }

    // Auto sessions are bit-identical to the unfused oracle and
    // buffer-stable across batch sizes, like every explicit arm.
    let mut session = plan.session();
    let sig = session.buffer_signature();
    let mut rng = Rng::new(2024);
    for case in 0..6 {
        let b = [1, 3, MAX_BATCH][case % 3];
        let x = images(&mut rng, b);
        let want = engine.forward_reference(&x, kernel);
        let got = session.run(&x);
        assert_eq!(got.max_abs_diff(&want), 0.0,
                   "auto plan diverged at b={b}");
        assert_eq!(session.buffer_signature(), sig,
                   "auto session reallocated (b={b})");
    }
}

#[test]
fn evaluate_runs_borrowed_batches_through_one_session() {
    let engine = synthetic_engine(WIDTHS, 76);
    let mut rng = Rng::new(11);
    let n = 10;
    let xs = images(&mut rng, n);
    let labels: Vec<u8> = (0..n).map(|i| (i % 10) as u8).collect();
    let kernel = EngineKernel::Xnor(XnorImpl::Blocked);
    // batch 4 exercises a ragged final batch of 2
    let acc = engine.evaluate(&xs, &labels, kernel, 4);
    let logits = engine.forward_reference(&xs, kernel);
    let correct = (0..n)
        .filter(|&i| argmax(logits.row(i)) == labels[i] as usize)
        .count();
    assert_eq!(acc, correct as f32 / n as f32);
}
