//! Acceptance suite for the epoll event-loop front end
//! (`serve --event-loop`): bit-identity with `forward_reference` over
//! real TCP, deadline mapping, reactor liveness under slow requests,
//! connection caps, and sustained concurrent keep-alive traffic.
//! Linux-only (epoll).
#![cfg(target_os = "linux")]

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use bitkernel::bitops::XnorImpl;
use bitkernel::coordinator::{
    Backend, BatcherConfig, MockBackend, NativeBackend, Router,
    RouterConfig,
};
use bitkernel::data::normalize_batch;
use bitkernel::model::{BnnEngine, EngineKernel, NetSpec};
use bitkernel::server::{serve, ServeOptions, Service};
use bitkernel::testing::synthetic_weight_file;
use bitkernel::utils::json::Json;

const KERNEL: EngineKernel = EngineKernel::Xnor(XnorImpl::Auto);

/// Spawn `service` behind the event-loop front end; returns the bound
/// address, stop flag, and the server thread.
fn spawn_event_loop(
    service: Arc<Service>,
    max_connections: usize,
    io_threads: usize,
) -> (
    std::net::SocketAddr,
    Arc<AtomicBool>,
    std::thread::JoinHandle<()>,
) {
    let stop = Arc::new(AtomicBool::new(false));
    let (ready_tx, ready_rx) = std::sync::mpsc::channel();
    let stop2 = Arc::clone(&stop);
    let server = std::thread::spawn(move || {
        serve(
            service,
            &ServeOptions {
                addr: "127.0.0.1:0".into(),
                threads: 2,
                max_connections,
                idle_timeout: Duration::from_secs(10),
                event_loop: true,
                io_threads,
            },
            stop2,
            Some(ready_tx),
        )
        .unwrap();
    });
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).unwrap();
    (addr, stop, server)
}

/// A mock 3x32x32/10 service with per-batch `latency_ms`.
fn mock_service(latency_ms: u64) -> Arc<Service> {
    let mut routers = BTreeMap::new();
    routers.insert(
        "m".to_string(),
        Router::start(
            move |_| {
                Ok(Box::new(MockBackend::new(8, latency_ms))
                    as Box<dyn Backend>)
            },
            RouterConfig { replicas: 2, ..RouterConfig::default() },
        )
        .unwrap(),
    );
    Arc::new(Service::new(routers, "m"))
}

fn http_get(addr: &std::net::SocketAddr, path: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(stream,
           "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .unwrap();
    read_one_response(&mut BufReader::new(stream))
}

fn http_post(addr: &std::net::SocketAddr, path: &str, body: &[u8])
             -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )
    .unwrap();
    stream.write_all(body).unwrap();
    read_one_response(&mut BufReader::new(stream))
}

/// One framed response; the reader stays positioned for the next
/// keep-alive reply.
fn read_one_response(
    reader: &mut BufReader<TcpStream>,
) -> (u16, String) {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 =
        status_line.split(' ').nth(1).unwrap().parse().unwrap();
    let mut len = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line.to_lowercase().strip_prefix("content-length:")
        {
            len = v.trim().parse().unwrap();
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}

#[test]
fn event_loop_is_bit_identical_to_forward_reference() {
    // A real compiled engine, not a mock: the event-loop path must
    // produce byte-for-byte the same logits as the unfused oracle.
    let spec = NetSpec::builder((3, 32, 32))
        .conv(8, 3)
        .pool()
        .linear(10)
        .build()
        .unwrap();
    let wf = synthetic_weight_file(&spec, 41);
    let engine = BnnEngine::from_weight_file(&wf).unwrap();
    let plan = engine.plan(KERNEL, 4).unwrap();
    let mut routers = BTreeMap::new();
    routers.insert(
        "net".to_string(),
        Router::start(
            move |_| {
                Ok(Box::new(NativeBackend::from_plan(&plan))
                    as Box<dyn Backend>)
            },
            RouterConfig {
                queue_cap: 64,
                replicas: 2,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_delay: Duration::from_millis(2),
                },
                ..RouterConfig::default()
            },
        )
        .unwrap(),
    );
    let service = Arc::new(Service::new(routers, "net"));
    let (addr, stop, server) =
        spawn_event_loop(service, 256, 2);

    // The discovery surface works over the event loop too.
    let (status, models) = http_get(&addr, "/models");
    assert_eq!(status, 200);
    let v = Json::parse(&models).unwrap();
    assert_eq!(v.as_arr().unwrap().len(), 1);

    for salt in 0..4usize {
        let px: Vec<u8> = (0..3 * 32 * 32)
            .map(|i| ((i * 31 + salt * 7) % 256) as u8)
            .collect();
        let x = normalize_batch(&px, 1, 32, 32, 3);
        let reference = engine.forward_reference(&x, KERNEL);
        let (status, body) = http_post(&addr, "/classify", &px);
        assert_eq!(status, 200, "salt {salt}: {body}");
        let v = Json::parse(&body).unwrap();
        let logits: Vec<f32> = v
            .get("logits")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_f64().unwrap() as f32)
            .collect();
        for (i, (&got, &want)) in
            logits.iter().zip(reference.data()).enumerate()
        {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "salt {salt} logit {i}: the event-loop path must be \
                 bit-identical to forward_reference"
            );
        }
    }

    // Wrong byte counts are still typed 400s, not parser wedges.
    let (status, body) = http_post(&addr, "/classify", &[1u8; 16]);
    assert_eq!(status, 400, "{body}");

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
}

#[test]
fn deadlines_map_to_504_and_generous_budgets_answer() {
    let (addr, stop, server) =
        spawn_event_loop(mock_service(200), 64, 1);
    let img = vec![3u8; 3 * 32 * 32];
    let (status, body) =
        http_post(&addr, "/classify?timeout_ms=1", &img);
    assert_eq!(status, 504, "{body}");
    assert!(body.contains("deadline"), "{body}");
    let (status, body) =
        http_post(&addr, "/classify?timeout_ms=10000", &img);
    assert_eq!(status, 200, "{body}");
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
}

#[test]
fn slow_inference_never_blocks_the_reactor() {
    // One classify against a 1.5 s-per-batch model is in flight;
    // /healthz and the (403) admin surface on other connections must
    // answer immediately — the reactor never waits on a replica.
    let (addr, stop, server) =
        spawn_event_loop(mock_service(1_500), 64, 1);
    let mut slow = TcpStream::connect(addr).unwrap();
    let img = vec![5u8; 3 * 32 * 32];
    write!(
        slow,
        "POST /classify HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
        img.len()
    )
    .unwrap();
    slow.write_all(&img).unwrap();
    // Give the request time to reach the replica.
    std::thread::sleep(Duration::from_millis(100));
    let t0 = Instant::now();
    let (status, _) = http_get(&addr, "/healthz");
    assert_eq!(status, 200);
    let mut put = TcpStream::connect(addr).unwrap();
    write!(put, "PUT /models/m HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let (status, _) =
        read_one_response(&mut BufReader::new(put));
    assert_eq!(status, 403, "admin disabled answers typed");
    assert!(
        t0.elapsed() < Duration::from_millis(1_000),
        "fast routes stalled {:?} behind a slow classify",
        t0.elapsed()
    );
    // The slow request itself still resolves.
    let (status, body) =
        read_one_response(&mut BufReader::new(slow));
    assert_eq!(status, 200, "{body}");
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
}

#[test]
fn over_limit_connections_shed_503_with_retry_after() {
    let service = mock_service(0);
    let (addr, stop, server) =
        spawn_event_loop(Arc::clone(&service), 4, 1);
    // Fill the cap with keep-alive connections that have each proven
    // themselves with one request.
    let img = vec![2u8; 3 * 32 * 32];
    let mut held = Vec::new();
    for _ in 0..4 {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(
            s,
            "POST /classify HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
            img.len()
        )
        .unwrap();
        s.write_all(&img).unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let (status, _) = read_one_response(&mut reader);
        assert_eq!(status, 200);
        held.push((s, reader));
    }
    // The fifth is shed at the door with a retry hint.
    let mut extra = TcpStream::connect(addr).unwrap();
    write!(extra, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    extra
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    let mut raw = String::new();
    let _ = extra.read_to_string(&mut raw);
    assert!(raw.starts_with("HTTP/1.1 503"), "{raw}");
    assert!(raw.contains("Retry-After"), "{raw}");
    assert!(
        service
            .http_metrics()
            .rejected_over_limit
            .load(Ordering::Relaxed)
            >= 1
    );
    // The held connections are still serviceable.
    let (s, reader) = &mut held[0];
    write!(s, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let (status, _) = read_one_response(reader);
    assert_eq!(status, 200);
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
}

#[test]
fn sustains_concurrent_keepalive_connections_without_loss() {
    const CONNS: usize = 96;
    const REQS: usize = 4;
    let service = mock_service(0);
    let (addr, stop, server) =
        spawn_event_loop(Arc::clone(&service), 512, 2);
    // Open every connection up front (all concurrently registered),
    // then round-robin requests over the set so keep-alive reuse and
    // the reactors' slabs are genuinely exercised.
    let mut conns: Vec<(TcpStream, BufReader<TcpStream>)> = (0..CONNS)
        .map(|_| {
            let s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(30)))
                .unwrap();
            let r = BufReader::new(s.try_clone().unwrap());
            (s, r)
        })
        .collect();
    let img = vec![6u8; 3 * 32 * 32];
    let mut ok = 0usize;
    for round in 0..REQS {
        for (s, reader) in conns.iter_mut() {
            write!(
                s,
                "POST /classify HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
                img.len()
            )
            .unwrap();
            s.write_all(&img).unwrap();
            let (status, body) = read_one_response(reader);
            assert_eq!(status, 200, "round {round}: {body}");
            ok += 1;
        }
    }
    assert_eq!(ok, CONNS * REQS, "no request may be lost");
    let m = service.http_metrics();
    assert!(
        m.accepts.load(Ordering::Relaxed) >= CONNS as u64,
        "every connection accept counted"
    );
    assert!(
        m.keepalive_reuses.load(Ordering::Relaxed)
            >= (CONNS * (REQS - 1)) as u64,
        "reuses counted per keep-alive request"
    );
    drop(conns);
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
}
