//! Quantization-scheme conformance matrix — the pinning test for the
//! scheme axis (sign·sign, XNOR α-scaling, binary-weight, ternary).
//!
//! Every cell of scheme × kernel implementation × topology must be
//! bit-identical (f32 bit patterns, not epsilon-close) to the
//! scheme-aware unfused oracle `BnnEngine::forward_reference`:
//!
//! * schemes:    all of [`QuantScheme::ALL`]
//! * kernels:    Scalar / Wide / Simd / Blocked2x4 / Threaded(2) / Auto
//!               on the packed arm, plus the Control and Optimized
//!               float arms
//! * topologies: fc-only, mixed binarization, non-square conv stacks,
//!               ragged K/D/N, plus a randomized draw
//!
//! On top of the matrix: BKW2 round-trips the scheme in both
//! directions, legacy (scheme-less) files load as the sign·sign
//! default, the wire bytes are pinned so the python exporter cannot
//! drift, and the python-generated fixtures under tests/fixtures/ are
//! pinned bit-for-bit (the python twin is
//! python/tests/test_cross_language.py).

use bitkernel::bitops::XnorImpl;
use bitkernel::model::{
    BnnEngine, EngineKernel, LayerSpec, NetSpec, QuantScheme, WeightFile,
};
use bitkernel::testing::{prop_assert, synthetic_engine_spec,
                         synthetic_weight_file};
use bitkernel::tensor::Tensor;
use bitkernel::utils::Rng;

/// The kernel axis: every packed tier that resolves differently, plus
/// the two float Table-2 arms.
fn kernels() -> [EngineKernel; 8] {
    [
        EngineKernel::Xnor(XnorImpl::Scalar),
        EngineKernel::Xnor(XnorImpl::Wide),
        EngineKernel::Xnor(XnorImpl::Simd),
        EngineKernel::Xnor(XnorImpl::Blocked2x4),
        EngineKernel::Xnor(XnorImpl::Threaded(2)),
        EngineKernel::Xnor(XnorImpl::Auto),
        EngineKernel::Control,
        EngineKernel::Optimized,
    ]
}

fn images_for(spec: &NetSpec, rng: &mut Rng, b: usize) -> Tensor {
    let (c, h, w) = spec.input();
    Tensor::new(vec![b, c, h, w], rng.normal_vec(b * c * h * w))
}

/// f32 bit patterns — the matrix asserts BIT identity, so that an
/// epilogue emitting -0.0 where the oracle emits +0.0 still fails.
fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|v| v.to_bits()).collect()
}

/// One matrix cell: compiled sessions on every kernel, two batch
/// sizes, bit-identical to the scheme-aware oracle.
fn assert_cell(engine: &BnnEngine, tag: &str) {
    let mut rng = Rng::new(0x5CEE ^ tag.len() as u64);
    for kernel in kernels() {
        let mut session = engine
            .plan(kernel, 3)
            .unwrap_or_else(|e| panic!("{tag}: plan failed: {e}"))
            .session();
        for b in [1, 3] {
            let x = images_for(&engine.spec, &mut rng, b);
            let want = engine.forward_reference(&x, kernel);
            let got = session.run(&x);
            assert_eq!(got.shape(), want.shape(), "{tag} {kernel:?} b={b}");
            assert_eq!(
                bits(got),
                bits(&want),
                "{tag} {kernel:?} b={b}: plan diverged from oracle"
            );
        }
    }
}

/// The fixed-topology axis, built fresh for each scheme.  The builder
/// drops `Sign` ops automatically under real-activation schemes, so
/// the same chains are valid for all four.
fn topologies(scheme: QuantScheme) -> Vec<(&'static str, NetSpec)> {
    vec![
        (
            // Ragged flatten width (70 = 2 words + 6 bits), real first
            // fc feeding a binarized one.
            "fc-only",
            NetSpec::builder((1, 1, 70))
                .linear(9)
                .linear(4)
                .scheme(scheme)
                .build()
                .expect("fc-only"),
        ),
        (
            // Non-binarized fc mid-chain: the plan leaves and re-enters
            // the scheme's packed/scaled domain.
            "fc-mixed",
            NetSpec::builder((2, 4, 4))
                .linear(20)
                .linear_opts(12, false)
                .linear(5)
                .scheme(scheme)
                .build()
                .expect("fc-mixed"),
        ),
        (
            // Non-square conv stack with a pool, ragged class count.
            "conv-nonsquare",
            NetSpec::builder((2, 10, 6))
                .conv(5, 3)
                .pool()
                .conv(7, 3)
                .linear(11)
                .linear(4)
                .scheme(scheme)
                .build()
                .expect("conv-nonsquare"),
        ),
        (
            // Odd input dims, 1x1 then 3x3 convs, ragged D/N.
            "conv-ragged",
            NetSpec::builder((3, 7, 9))
                .conv(4, 1)
                .conv(6, 3)
                .linear(33)
                .linear(3)
                .scheme(scheme)
                .build()
                .expect("conv-ragged"),
        ),
    ]
}

/// The python fixture topology (fc-only, EVERY fc binarized — the
/// builder can't express a binarized first layer, so built by hand).
fn fixture_spec(scheme: QuantScheme) -> NetSpec {
    let mut layers = vec![LayerSpec::Flatten];
    for dout in [9usize, 4] {
        if scheme.signs_activations() {
            layers.push(LayerSpec::Sign);
        }
        layers.push(LayerSpec::Linear { dout, binarized: true });
        layers.push(LayerSpec::BatchNorm);
    }
    NetSpec::new_with_scheme((1, 1, 70), layers, scheme)
        .expect("fixture spec")
}

// ---------------------------------------------------------------------------
// the matrix
// ---------------------------------------------------------------------------

#[test]
fn matrix_every_scheme_kernel_topology_is_bit_identical() {
    for scheme in QuantScheme::ALL {
        for (name, spec) in topologies(scheme) {
            assert_eq!(spec.scheme(), scheme, "{name}");
            let seed = 0x9C00 + u64::from(scheme.wire_byte());
            let engine = synthetic_engine_spec(&spec, seed);
            assert_cell(&engine, &format!("{}/{}", scheme.name(), name));
        }
    }
}

#[test]
fn matrix_fixture_topology_all_layers_binarized() {
    for scheme in QuantScheme::ALL {
        let engine = synthetic_engine_spec(&fixture_spec(scheme), 4242);
        assert_cell(&engine, &format!("{}/fixture", scheme.name()));
    }
}

#[test]
fn prop_matrix_random_topologies_bit_identical() {
    prop_assert(0x5CEEA11, 8, |rng, case| {
        let scheme = QuantScheme::ALL[rng.below(4)];
        let spec = random_spec(rng, scheme);
        let engine = synthetic_engine_spec(&spec, 7000 + case as u64);
        for kernel in kernels() {
            let mut session = engine
                .plan(kernel, 2)
                .map_err(|e| format!("case {case}: plan: {e}"))?
                .session();
            for b in [1, 2] {
                let x = images_for(&spec, rng, b);
                let want = engine.forward_reference(&x, kernel);
                let got = session.run(&x);
                if bits(got) != bits(&want) {
                    return Err(format!(
                        "case {case} {} {kernel:?} b={b}: plan \
                         diverged from oracle (spec {spec:?})",
                        scheme.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Random-but-valid spec under a scheme: conv nets over odd shapes or
/// fc-only nets, occasional non-binarized layers mid-net (the
/// tests/netspec.rs draw, scheme-parameterized).
fn random_spec(rng: &mut Rng, scheme: QuantScheme) -> NetSpec {
    if rng.below(3) == 0 {
        let c = 1 + rng.below(3);
        let h = 2 + rng.below(5);
        let w = 2 + rng.below(5);
        let mut b = NetSpec::builder((c, h, w)).linear(6 + rng.below(30));
        b = if rng.below(2) == 0 {
            b.linear_opts(4 + rng.below(20), false)
        } else {
            b.linear(4 + rng.below(20))
        };
        return b
            .linear(2 + rng.below(15))
            .scheme(scheme)
            .build()
            .expect("fc-only random spec");
    }
    let c = 1 + rng.below(3);
    let h = 2 * (3 + rng.below(3));
    let w = 2 * (3 + rng.below(3));
    let mut b = NetSpec::builder((c, h, w));
    let nconv = 1 + rng.below(2);
    for i in 0..nconv {
        let cout = 2 + rng.below(6);
        let ksize = [1, 3][rng.below(2)];
        b = if i > 0 && rng.below(4) == 0 {
            b.conv_opts(cout, ksize, 1, ksize / 2, false)
        } else {
            b.conv(cout, ksize)
        };
    }
    if rng.below(2) == 0 {
        b = b.pool();
    }
    b.linear(2 + rng.below(15))
        .scheme(scheme)
        .build()
        .expect("conv random spec")
}

// ---------------------------------------------------------------------------
// BKW2 scheme round trip + legacy default
// ---------------------------------------------------------------------------

#[test]
fn bkw2_round_trips_scheme_and_logits_for_every_scheme() {
    for scheme in QuantScheme::ALL {
        let (_, spec) = topologies(scheme).remove(2); // conv-nonsquare
        let wf = synthetic_weight_file(&spec, 808);
        let back = WeightFile::parse(&wf.to_bytes()[..])
            .unwrap_or_else(|e| panic!("{}: parse: {e}", scheme.name()));
        let embedded = back.embedded_spec().expect("BKW2 carries its spec");
        assert_eq!(embedded.scheme(), scheme);
        assert_eq!(embedded, &spec);

        let before = BnnEngine::from_weight_file(&wf).unwrap();
        let after = BnnEngine::from_weight_file(&back).unwrap();
        let mut rng = Rng::new(11);
        let x = images_for(&spec, &mut rng, 2);
        for kernel in [EngineKernel::Xnor(XnorImpl::Auto),
                       EngineKernel::Control] {
            assert_eq!(
                bits(&before.forward_reference(&x, kernel)),
                bits(&after.forward_reference(&x, kernel)),
                "{} {kernel:?}",
                scheme.name()
            );
        }
    }
}

#[test]
fn legacy_scheme_less_files_load_as_the_default() {
    // A default-scheme spec writes no scheme op, and what it writes
    // reads back as the default — i.e. pre-scheme BKW2 files (and
    // BKW1, covered in tests/netspec.rs) keep loading unchanged.
    let spec = NetSpec::builder((1, 4, 4)).linear(6).linear(3).build()
        .unwrap();
    assert!(spec.scheme().is_default());
    let bytes = synthetic_weight_file(&spec, 5).to_bytes();
    let back = WeightFile::parse(&bytes[..]).unwrap();
    assert!(back.embedded_spec().unwrap().scheme().is_default());
}

#[test]
fn scheme_wire_bytes_and_names_are_pinned() {
    // The cross-language contract: python's train.SCHEMES dict must
    // agree byte-for-byte and name-for-name.  Changing either side is
    // a format break, not a refactor.
    let names: Vec<&str> =
        QuantScheme::ALL.iter().map(|s| s.name()).collect();
    assert_eq!(
        names,
        ["sign_sign", "xnor_alpha", "binary_weight", "ternary_weight"]
    );
    for (i, scheme) in QuantScheme::ALL.into_iter().enumerate() {
        assert_eq!(scheme.wire_byte(), i as u8);
        assert_eq!(QuantScheme::from_wire_byte(i as u8), Some(scheme));
    }
    assert_eq!(QuantScheme::from_wire_byte(4), None);
}

#[test]
fn plans_report_their_scheme_and_resolve_auto() {
    for scheme in QuantScheme::ALL {
        let engine = synthetic_engine_spec(&fixture_spec(scheme), 31);
        let plan =
            engine.plan(EngineKernel::Xnor(XnorImpl::Auto), 2).unwrap();
        assert_eq!(plan.scheme(), scheme);
        assert!(
            plan.xnor_impls().iter().all(|i| *i != XnorImpl::Auto),
            "{}: Auto must resolve at plan time",
            scheme.name()
        );
    }
}

// ---------------------------------------------------------------------------
// python-generated cross-language fixtures
// ---------------------------------------------------------------------------

/// The fixture input, mirroring _fx_input in
/// python/tests/test_cross_language.py: x[b,i] = ((7i + 3(b+1)) % 11) - 5.
fn fixture_input() -> Tensor {
    const K: usize = 70;
    const B: usize = 2;
    let mut data = Vec::with_capacity(B * K);
    for b in 0..B {
        for i in 0..K {
            data.push(((7 * i + 3 * (b + 1)) % 11) as f32 - 5.0);
        }
    }
    Tensor::new(vec![B, 1, 1, K], data)
}

/// Parse a .logits sidecar: one line per batch row of space-separated
/// u32 hex f32 bit patterns.
fn read_logits_bits(path: &str) -> Vec<u32> {
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("{path}: {e} (regenerate with \
            `python python/tests/test_cross_language.py`)"))
        .split_whitespace()
        .map(|t| u32::from_str_radix(t, 16)
            .unwrap_or_else(|e| panic!("{path}: bad hex '{t}': {e}")))
        .collect()
}

#[test]
fn python_fixtures_pin_every_scheme_bit_identical() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures");
    for scheme in QuantScheme::ALL {
        let name = scheme.name();
        let raw = std::fs::read(format!("{dir}/scheme_{name}.bkw"))
            .unwrap_or_else(|e| panic!("scheme_{name}.bkw: {e} \
                (regenerate with \
                `python python/tests/test_cross_language.py`)"));
        let wf = WeightFile::parse(&raw[..])
            .unwrap_or_else(|e| panic!("scheme_{name}.bkw: {e}"));
        let engine = BnnEngine::from_weight_file(&wf)
            .unwrap_or_else(|e| panic!("scheme_{name}.bkw: {e}"));
        assert_eq!(engine.spec.scheme(), scheme);
        assert_eq!(engine.spec, fixture_spec(scheme));

        let want = read_logits_bits(&format!("{dir}/scheme_{name}.logits"));
        assert_eq!(want.len(), 2 * 4, "{name}: sidecar shape");
        let x = fixture_input();
        for kernel in kernels() {
            let oracle = engine.forward_reference(&x, kernel);
            assert_eq!(
                bits(&oracle),
                want,
                "{name} {kernel:?}: oracle diverged from python logits"
            );
            let mut session = engine.plan(kernel, 2).unwrap().session();
            assert_eq!(
                bits(session.run(&x)),
                want,
                "{name} {kernel:?}: plan diverged from python logits"
            );
        }
    }
}
