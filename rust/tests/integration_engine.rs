//! Native-engine integration tests over the real artifacts.
//!
//! Requires `make artifacts` (skipped with a note otherwise).  Pins the
//! paper's core premise: the three kernels compute the SAME network.

use bitkernel::bitops::XnorImpl;
use bitkernel::data::Dataset;
use bitkernel::model::{BnnEngine, EngineKernel};
use bitkernel::tensor::Tensor;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn load_small(dir: &std::path::Path) -> (BnnEngine, Dataset) {
    let engine = BnnEngine::load(dir.join("weights_small.bkw")).unwrap();
    let ds = Dataset::load(dir.join("dataset_test.bin")).unwrap();
    (engine, ds)
}

#[test]
fn all_arms_identical_logits() {
    let Some(dir) = artifacts() else { return };
    let (engine, ds) = load_small(&dir);
    let x = ds.normalized(0, 4);
    let reference = engine.forward(&x, EngineKernel::Optimized);
    for kernel in [
        EngineKernel::Control,
        EngineKernel::Xnor(XnorImpl::Scalar),
        EngineKernel::Xnor(XnorImpl::Word64),
        EngineKernel::Xnor(XnorImpl::Blocked),
        EngineKernel::Xnor(XnorImpl::Wide),
        EngineKernel::Xnor(XnorImpl::Simd),
        EngineKernel::Xnor(XnorImpl::Auto),
        EngineKernel::Xnor(XnorImpl::Threaded(2)),
    ] {
        let logits = engine.forward(&x, kernel);
        let diff = logits.max_abs_diff(&reference);
        // Binarized layers are exact; conv1's float path may differ in
        // summation order between naive and blocked gemm -> tiny eps.
        assert!(diff <= 2e-3, "{} vs optimized: {diff}", kernel.name());
    }
}

#[test]
fn trained_model_beats_chance_by_far() {
    let Some(dir) = artifacts() else { return };
    let (engine, ds) = load_small(&dir);
    let n = 256.min(ds.count);
    let x = ds.normalized(0, n);
    let acc = engine.evaluate(&x, &ds.labels[..n],
                              EngineKernel::Xnor(XnorImpl::Blocked), 32);
    // python-side training reached ~1.0; anything >= 0.9 proves the full
    // rust pipeline (BKD + BKW + engine) reproduces it.
    assert!(acc >= 0.9, "accuracy {acc}");
}

#[test]
fn accuracy_identical_across_arms() {
    let Some(dir) = artifacts() else { return };
    let (engine, ds) = load_small(&dir);
    let n = 128.min(ds.count);
    let x = ds.normalized(0, n);
    let acc_x = engine.evaluate(&x, &ds.labels[..n],
                                EngineKernel::Xnor(XnorImpl::Blocked), 16);
    let acc_c = engine.evaluate(&x, &ds.labels[..n], EngineKernel::Control, 16);
    let acc_o = engine.evaluate(&x, &ds.labels[..n], EngineKernel::Optimized, 16);
    assert_eq!(acc_x, acc_c);
    assert_eq!(acc_x, acc_o);
}

#[test]
fn full_scale_model_loads_and_runs() {
    let Some(dir) = artifacts() else { return };
    let engine = BnnEngine::load(dir.join("weights_full.bkw")).unwrap();
    assert!(engine.spec.param_count() > 13_000_000);
    let x = Tensor::zeros(vec![1, 3, 32, 32]);
    let a = engine.forward(&x, EngineKernel::Xnor(XnorImpl::Blocked));
    let b = engine.forward(&x, EngineKernel::Optimized);
    assert_eq!(a.shape(), &[1, 10]);
    assert!(a.max_abs_diff(&b) <= 2e-3);
}

#[test]
fn batch_invariance() {
    // Running images singly or batched must give the same logits.
    let Some(dir) = artifacts() else { return };
    let (engine, ds) = load_small(&dir);
    let batch = engine.forward(&ds.normalized(0, 3),
                               EngineKernel::Xnor(XnorImpl::Blocked));
    for i in 0..3 {
        let single = engine.forward(&ds.normalized(i, i + 1),
                                    EngineKernel::Xnor(XnorImpl::Blocked));
        for c in 0..10 {
            assert_eq!(single.row(0)[c], batch.row(i)[c], "img {i} class {c}");
        }
    }
}
