//! Chaos suite: fault-injection drills against the real serving
//! pipeline (native engine, replicated router, registry).
//!
//! The acceptance property, end to end: under injected replica panics
//! and inference delays, EVERY client gets either a correct reply or a
//! typed error within its deadline — zero hangs, zero silent drops —
//! the pool converges back to full replica strength, and every reply
//! that does arrive is bit-identical to `forward_reference`.
//!
//! Each test installs a `FaultPlan`; the install guard serializes the
//! tests against each other (process-global harness), so no test sees
//! another's faults.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use bitkernel::bitops::XnorImpl;
use bitkernel::coordinator::{
    Backend, BatcherConfig, MockBackend, NativeBackend, ReplyError,
    RequestError, Router, RouterConfig, SubmitError, SubmitOptions,
};
use bitkernel::data::normalize_batch;
use bitkernel::model::{EngineKernel, NetSpec};
use bitkernel::server::{ModelRegistry, ModelState, RegistryConfig};
use bitkernel::testing::chaos::FaultPlan;
use bitkernel::testing::{synthetic_engine, synthetic_weight_file};

const KERNEL: EngineKernel = EngineKernel::Xnor(XnorImpl::Auto);

/// Deterministic fake image bytes (same generator as tests/serving.rs).
fn pixels(salt: usize) -> Vec<u8> {
    (0..3 * 32 * 32).map(|i| ((i * 31 + salt * 7) % 256) as u8).collect()
}

#[test]
fn hammered_router_survives_injected_panics_without_hangs() {
    let engine = synthetic_engine([8, 8, 8, 8, 8, 8, 16, 16, 10], 42);
    let plan = engine.plan(KERNEL, 4).unwrap();

    // Per-image oracle through the unfused reference path: surviving
    // replies must be bit-identical to it, panics notwithstanding.
    let n_salts = 8usize;
    let oracles: Vec<Vec<f32>> = (0..n_salts)
        .map(|s| {
            let x = normalize_batch(&pixels(s), 1, 32, 32, 3);
            engine.forward_reference(&x, KERNEL).data().to_vec()
        })
        .collect();
    let images: Vec<Vec<f32>> = (0..n_salts)
        .map(|s| normalize_batch(&pixels(s), 1, 32, 32, 3).into_data())
        .collect();

    // Two scheduled one-shot panics plus a small per-batch delay that
    // keeps batches in flight long enough for clients to pile up
    // behind the faults.
    let guard = FaultPlan::new()
        .delay(Duration::from_millis(2))
        .panic_on(1, 2)
        .panic_on(3, 4)
        .install();

    let router = Arc::new(
        Router::start(
            move |_replica| {
                Ok(Box::new(NativeBackend::from_plan(&plan))
                    as Box<dyn Backend>)
            },
            RouterConfig {
                queue_cap: 256,
                replicas: 4,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_delay: Duration::from_millis(1),
                },
                ..RouterConfig::default()
            },
        )
        .unwrap(),
    );

    let clients = 8usize;
    let per_client = 30usize;
    let ok = Arc::new(AtomicUsize::new(0));
    let panicked = Arc::new(AtomicUsize::new(0));
    let mut handles = Vec::new();
    for t in 0..clients {
        let router = Arc::clone(&router);
        let images = images.clone();
        let oracles = oracles.clone();
        let ok = Arc::clone(&ok);
        let panicked = Arc::clone(&panicked);
        handles.push(std::thread::spawn(move || {
            for i in 0..per_client {
                let salt = (t * per_client + i) % images.len();
                loop {
                    match router.submit_wait_deadline(
                        images[salt].clone(),
                        SubmitOptions::with_timeout(Duration::from_secs(
                            30,
                        )),
                    ) {
                        Ok(reply) => {
                            assert_eq!(
                                reply.logits.len(),
                                oracles[salt].len()
                            );
                            for (j, (&got, &want)) in reply
                                .logits
                                .iter()
                                .zip(&oracles[salt])
                                .enumerate()
                            {
                                assert_eq!(
                                    got.to_bits(),
                                    want.to_bits(),
                                    "salt {salt} logit {j}: {got} vs \
                                     {want} — chaos must never corrupt \
                                     a surviving reply"
                                );
                            }
                            ok.fetch_add(1, Ordering::SeqCst);
                            break;
                        }
                        Err(RequestError::Rejected(
                            SubmitError::QueueFull,
                        )) => std::thread::yield_now(),
                        Err(RequestError::Failed(
                            ReplyError::ReplicaPanicked { .. },
                        )) => {
                            panicked.fetch_add(1, Ordering::SeqCst);
                            break;
                        }
                        // DeadlineExceeded here would mean a hung
                        // request — the exact bug supervision exists
                        // to prevent — so it fails the test, as does
                        // any other error.
                        Err(e) => {
                            panic!("client {t} request {i}: {e}")
                        }
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    // Accounting closes: every request ended in a reply or a typed
    // panic error, within its deadline.
    let ok = ok.load(Ordering::SeqCst);
    let panicked = panicked.load(Ordering::SeqCst);
    assert_eq!(ok + panicked, clients * per_client);
    assert!(panicked >= 1, "the scheduled panics must strand requests");

    // The pool converges back to full replica strength.
    let t0 = Instant::now();
    while router.healthy_replicas() < 4 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "pool never recovered to 4 healthy replicas"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(!router.circuit_open());
    let snap = router.metrics().snapshot();
    assert_eq!(snap.panics, 2, "exactly the two scheduled faults");
    let restarts: u64 = snap.replicas.iter().map(|r| r.restarts).sum();
    assert_eq!(restarts, 2, "every panic respawns exactly once");
    assert_eq!(snap.completed, ok as u64);
    drop(guard);
}

#[test]
fn circuit_opens_while_every_replica_restarts_then_recloses() {
    // The factory refuses to rebuild while `factory_down` holds, so
    // panicked replicas stay in their backoff loop — that is the
    // all-replicas-restarting state the circuit breaker reports.
    let factory_down = Arc::new(AtomicBool::new(false));
    let down = Arc::clone(&factory_down);
    let router = Arc::new(
        Router::start(
            move |_replica| {
                anyhow::ensure!(
                    !down.load(Ordering::SeqCst),
                    "chaos: factory down"
                );
                Ok(Box::new(MockBackend::new(2, 0)) as Box<dyn Backend>)
            },
            RouterConfig {
                queue_cap: 16,
                replicas: 2,
                batcher: BatcherConfig {
                    max_batch: 2,
                    max_delay: Duration::from_millis(1),
                },
                ..RouterConfig::default()
            },
        )
        .unwrap(),
    );
    assert_eq!(router.healthy_replicas(), 2);
    assert!(!router.circuit_open());

    let guard = FaultPlan::new().install();
    factory_down.store(true, Ordering::SeqCst);
    guard.plan().arm_panic(0);
    guard.plan().arm_panic(1);
    // One request per replica trips both armed faults; each comes back
    // as a typed error, not a hang.
    for i in 0..2 {
        let err = router
            .submit_wait(vec![0.0; 3 * 32 * 32])
            .expect_err("armed fault must strand the request");
        assert!(
            matches!(
                err,
                RequestError::Failed(ReplyError::ReplicaPanicked { .. })
            ),
            "request {i}: {err}"
        );
    }
    // Both replicas are now looping on the dead factory.
    let t0 = Instant::now();
    while !router.circuit_open() {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "circuit never opened with every replica down"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(router.healthy_replicas(), 0);

    // Restore the factory: the backoff loop respawns both replicas and
    // the circuit recloses without any external intervention.
    factory_down.store(false, Ordering::SeqCst);
    let t0 = Instant::now();
    while router.healthy_replicas() < 2 {
        assert!(
            t0.elapsed() < Duration::from_secs(15),
            "pool never recovered after the factory came back"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(!router.circuit_open());
    let reply = router.submit_wait(vec![0.25; 3 * 32 * 32]).unwrap();
    assert_eq!(reply.logits.len(), 10);
    let snap = router.metrics().snapshot();
    assert_eq!(snap.panics, 2);
    assert_eq!(
        snap.replicas.iter().map(|r| r.restarts).sum::<u64>(),
        2
    );
    drop(guard);
}

#[test]
fn injected_weight_read_faults_fail_mounts_typed_then_recover() {
    let dir = std::env::temp_dir()
        .join(format!("bk-chaos-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let spec = NetSpec::builder((1, 4, 4))
        .conv(2, 3)
        .linear(3)
        .build()
        .unwrap();
    let path = dir.join("m.bkw");
    synthetic_weight_file(&spec, 9).save(&path).unwrap();

    let guard = FaultPlan::new().fail_weight_reads(1).install();
    let reg = ModelRegistry::new(RegistryConfig::default());
    let entry = reg.mount("m", &path, false).unwrap();
    let st = entry.wait_settled(Duration::from_secs(30));
    assert_eq!(st.state, ModelState::Failed);
    assert!(
        st.error.as_deref().unwrap_or("").contains("chaos"),
        "the injected failure must be the stored, typed error: {:?}",
        st.error
    );

    // The fault budget is spent: remounting the same file succeeds.
    reg.unmount("m").unwrap();
    let entry = reg.mount("m", &path, false).unwrap();
    let st = entry.wait_settled(Duration::from_secs(30));
    assert_eq!(st.state, ModelState::Ready, "{:?}", st.error);
    let (router, _generation) = reg.router_for("m").unwrap();
    let reply =
        router.submit_wait(vec![0.5; router.image_elems()]).unwrap();
    assert_eq!(reply.logits.len(), 3);
    drop(guard);
    std::fs::remove_dir_all(&dir).ok();
}
