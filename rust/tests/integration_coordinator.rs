//! End-to-end coordinator tests: real engine behind the router, and the
//! HTTP service over a real TCP socket.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bitkernel::coordinator::{
    Backend, BatcherConfig, MockBackend, NativeBackend, Router, RouterConfig,
};
use bitkernel::data::Dataset;
use bitkernel::model::BnnEngine;
use bitkernel::server::{serve, ServeOptions, Service};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn router_with_native_engine_classifies_correctly() {
    let Some(dir) = artifacts() else { return };
    let ds = Dataset::load(dir.join("dataset_test.bin")).unwrap();
    let weights = dir.join("weights_small.bkw");
    let router = Router::start(
        move || {
            let engine = BnnEngine::load(&weights)?;
            Ok(Box::new(NativeBackend::xnor(&engine, 8)) as Box<dyn Backend>)
        },
        RouterConfig {
            queue_cap: 64,
            batcher: BatcherConfig {
                max_batch: 8,
                max_delay: Duration::from_millis(2),
            },
        },
    )
    .unwrap();

    let n = 32;
    let mut correct = 0;
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let img = ds.normalized(i, i + 1);
            router.submit(img.into_data()).unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx.recv().unwrap();
        if reply.class == ds.labels[i] as usize {
            correct += 1;
        }
    }
    assert!(correct >= 29, "{correct}/{n}"); // trained model: ~100%
    let snap = router.metrics().snapshot();
    assert_eq!(snap.completed, n as u64);
    assert!(snap.mean_batch_size > 1.0, "batching never kicked in");
}

#[test]
fn http_service_end_to_end() {
    let Some(dir) = artifacts() else { return };
    let ds = Dataset::load(dir.join("dataset_test.bin")).unwrap();
    let weights = dir.join("weights_small.bkw");

    let mut routers = BTreeMap::new();
    routers.insert(
        "bnn".to_string(),
        Router::start(
            move || {
                let engine = BnnEngine::load(&weights)?;
                Ok(Box::new(NativeBackend::xnor(&engine, 8)) as Box<dyn Backend>)
            },
            RouterConfig::default(),
        )
        .unwrap(),
    );
    let service = Arc::new(Service::new(routers, "bnn"));

    let stop = Arc::new(AtomicBool::new(false));
    let (ready_tx, ready_rx) = std::sync::mpsc::channel();
    let stop2 = Arc::clone(&stop);
    let svc2 = Arc::clone(&service);
    let server = std::thread::spawn(move || {
        serve(
            svc2,
            &ServeOptions { addr: "127.0.0.1:0".into(), threads: 2 },
            stop2,
            Some(ready_tx),
        )
        .unwrap();
    });
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).unwrap();

    // healthz
    let body = http_get(&addr, "/healthz");
    assert!(body.1.contains("ok"), "{body:?}");

    // classify 8 images, count correct
    let mut correct = 0;
    for i in 0..8 {
        let (status, body) =
            http_post(&addr, "/classify?model=bnn", ds.image(i));
        assert_eq!(status, 200, "{body}");
        let v = bitkernel::utils::json::Json::parse(&body).unwrap();
        let class = v.get("class").unwrap().as_usize().unwrap();
        assert!(v.get("latency_us").unwrap().as_f64().unwrap() > 0.0);
        if class == ds.labels[i] as usize {
            correct += 1;
        }
    }
    assert!(correct >= 7, "{correct}/8");

    // metrics reflect the traffic
    let (_, metrics) = http_get(&addr, "/metrics");
    assert!(metrics.contains("bitkernel_requests_completed{model=\"bnn\"} 8"),
            "{metrics}");

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
}

#[test]
fn service_supports_multiple_models() {
    // Two mock models: routing by ?model= must hit the right one.
    let mk = |batch| {
        Router::start(
            move || Ok(Box::new(MockBackend::new(batch, 0)) as Box<dyn Backend>),
            RouterConfig::default(),
        )
        .unwrap()
    };
    let mut routers = BTreeMap::new();
    routers.insert("a".to_string(), mk(1));
    routers.insert("b".to_string(), mk(4));
    let service = Arc::new(Service::new(routers, "a"));
    let stop = Arc::new(AtomicBool::new(false));
    let (ready_tx, ready_rx) = std::sync::mpsc::channel();
    let stop2 = Arc::clone(&stop);
    let svc2 = Arc::clone(&service);
    let server = std::thread::spawn(move || {
        serve(svc2, &ServeOptions { addr: "127.0.0.1:0".into(), threads: 2 },
              stop2, Some(ready_tx)).unwrap();
    });
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).unwrap();

    let img = vec![128u8; 3072];
    assert_eq!(http_post(&addr, "/classify?model=a", &img).0, 200);
    assert_eq!(http_post(&addr, "/classify?model=b", &img).0, 200);
    assert_eq!(http_post(&addr, "/classify?model=zz", &img).0, 404);
    let (_, models) = http_get(&addr, "/models");
    assert!(models.contains("\"a\"") && models.contains("\"b\""));

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
}

#[test]
fn failing_backend_drops_requests_and_counts_rejections() {
    /// Backend that errors on every batch (failure injection).
    struct FailingBackend;
    impl Backend for FailingBackend {
        fn name(&self) -> &str {
            "failing"
        }
        fn max_batch(&self) -> usize {
            4
        }
        fn infer(
            &mut self,
            _images: &bitkernel::tensor::Tensor,
        ) -> anyhow::Result<&bitkernel::tensor::Tensor> {
            anyhow::bail!("injected fault")
        }
    }
    let router = Router::start(
        || Ok(Box::new(FailingBackend) as Box<dyn Backend>),
        RouterConfig::default(),
    )
    .unwrap();
    let rx = router.submit(vec![0.0; 3 * 32 * 32]).unwrap();
    // The reply channel must disconnect (request dropped), not hang.
    assert!(rx.recv_timeout(Duration::from_secs(5)).is_err());
    let snap = router.metrics().snapshot();
    assert_eq!(snap.rejected, 1);
    assert_eq!(snap.completed, 0);
}

#[test]
fn backend_construction_failure_is_synchronous() {
    let r = Router::start(
        || anyhow::bail!("no such model"),
        RouterConfig::default(),
    );
    assert!(r.is_err());
    assert!(format!("{:#}", r.err().unwrap()).contains("no such model"));
}

// --- tiny test HTTP client -------------------------------------------------

fn http_get(addr: &std::net::SocketAddr, path: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(stream, "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .unwrap();
    read_response(stream)
}

fn http_post(addr: &std::net::SocketAddr, path: &str, body: &[u8]) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )
    .unwrap();
    stream.write_all(body).unwrap();
    read_response(stream)
}

fn read_response(stream: TcpStream) -> (u16, String) {
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line.split(' ').nth(1).unwrap().parse().unwrap();
    let mut len = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line.to_lowercase().strip_prefix("content-length:") {
            len = v.trim().parse().unwrap();
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}
