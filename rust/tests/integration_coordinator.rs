//! End-to-end coordinator tests: real engine behind the router, the
//! replica pool's contracts (bit-identical replies, drain, explicit
//! backpressure), and the HTTP service over a real TCP socket.
//!
//! Replica-pool tests run on a synthetic engine, so they need no
//! artifacts; only the trained-model tests self-skip.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bitkernel::bitops::XnorImpl;
use bitkernel::coordinator::{
    Backend, BatcherConfig, MockBackend, NativeBackend, Router, RouterConfig,
    SubmitError,
};
use bitkernel::data::Dataset;
use bitkernel::model::{BnnEngine, EngineKernel};
use bitkernel::server::{serve, ServeOptions, Service};
use bitkernel::testing::synthetic_engine;
use bitkernel::utils::Rng;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn router_with_native_engine_classifies_correctly() {
    let Some(dir) = artifacts() else { return };
    let ds = Dataset::load(dir.join("dataset_test.bin")).unwrap();
    let weights = dir.join("weights_small.bkw");
    let engine = BnnEngine::load(&weights).unwrap();
    let plan = engine.plan(EngineKernel::Xnor(XnorImpl::Auto), 8).unwrap();
    let router = Router::start(
        move |_replica| {
            Ok(Box::new(NativeBackend::from_plan(&plan)) as Box<dyn Backend>)
        },
        RouterConfig {
            queue_cap: 64,
            replicas: 2,
            batcher: BatcherConfig {
                max_batch: 8,
                max_delay: Duration::from_millis(2),
            },
            ..RouterConfig::default()
        },
    )
    .unwrap();

    let n = 32;
    let mut correct = 0;
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let img = ds.normalized(i, i + 1);
            router.submit(img.into_data()).unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx.recv().unwrap().unwrap();
        if reply.class == ds.labels[i] as usize {
            correct += 1;
        }
    }
    assert!(correct >= 29, "{correct}/{n}"); // trained model: ~100%
    let snap = router.metrics().snapshot();
    assert_eq!(snap.completed, n as u64);
    assert!(snap.mean_batch_size > 1.0, "batching never kicked in");
}

#[test]
fn http_service_end_to_end() {
    let Some(dir) = artifacts() else { return };
    let ds = Dataset::load(dir.join("dataset_test.bin")).unwrap();
    let weights = dir.join("weights_small.bkw");

    let engine = BnnEngine::load(&weights).unwrap();
    let plan = engine.plan(EngineKernel::Xnor(XnorImpl::Auto), 8).unwrap();
    let mut routers = BTreeMap::new();
    routers.insert(
        "bnn".to_string(),
        Router::start(
            move |_replica| {
                Ok(Box::new(NativeBackend::from_plan(&plan))
                    as Box<dyn Backend>)
            },
            RouterConfig::default(),
        )
        .unwrap(),
    );
    let service = Arc::new(Service::new(routers, "bnn"));

    let stop = Arc::new(AtomicBool::new(false));
    let (ready_tx, ready_rx) = std::sync::mpsc::channel();
    let stop2 = Arc::clone(&stop);
    let svc2 = Arc::clone(&service);
    let server = std::thread::spawn(move || {
        serve(
            svc2,
            &ServeOptions {
                addr: "127.0.0.1:0".into(),
                threads: 2,
                ..ServeOptions::default()
            },
            stop2,
            Some(ready_tx),
        )
        .unwrap();
    });
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).unwrap();

    // healthz
    let body = http_get(&addr, "/healthz");
    assert!(body.1.contains("ok"), "{body:?}");

    // classify 8 images, count correct
    let mut correct = 0;
    for i in 0..8 {
        let (status, body) =
            http_post(&addr, "/classify?model=bnn", ds.image(i));
        assert_eq!(status, 200, "{body}");
        let v = bitkernel::utils::json::Json::parse(&body).unwrap();
        let class = v.get("class").unwrap().as_usize().unwrap();
        assert!(v.get("latency_us").unwrap().as_f64().unwrap() > 0.0);
        if class == ds.labels[i] as usize {
            correct += 1;
        }
    }
    assert!(correct >= 7, "{correct}/8");

    // metrics reflect the traffic
    let (_, metrics) = http_get(&addr, "/metrics");
    assert!(metrics.contains("bitkernel_requests_completed{model=\"bnn\"} 8"),
            "{metrics}");

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
}

#[test]
fn service_supports_multiple_models() {
    // Two mock models: routing by ?model= must hit the right one.
    let mk = |batch| {
        Router::start(
            move |_| Ok(Box::new(MockBackend::new(batch, 0)) as Box<dyn Backend>),
            RouterConfig::default(),
        )
        .unwrap()
    };
    let mut routers = BTreeMap::new();
    routers.insert("a".to_string(), mk(1));
    routers.insert("b".to_string(), mk(4));
    let service = Arc::new(Service::new(routers, "a"));
    let stop = Arc::new(AtomicBool::new(false));
    let (ready_tx, ready_rx) = std::sync::mpsc::channel();
    let stop2 = Arc::clone(&stop);
    let svc2 = Arc::clone(&service);
    let server = std::thread::spawn(move || {
        serve(svc2,
              &ServeOptions {
                  addr: "127.0.0.1:0".into(),
                  threads: 2,
                  ..ServeOptions::default()
              },
              stop2, Some(ready_tx)).unwrap();
    });
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).unwrap();

    let img = vec![128u8; 3072];
    assert_eq!(http_post(&addr, "/classify?model=a", &img).0, 200);
    assert_eq!(http_post(&addr, "/classify?model=b", &img).0, 200);
    assert_eq!(http_post(&addr, "/classify?model=zz", &img).0, 404);
    let (_, models) = http_get(&addr, "/models");
    assert!(models.contains("\"a\"") && models.contains("\"b\""));

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
}

#[test]
fn failing_backend_answers_typed_errors_and_counts_rejections() {
    /// Backend that errors on every batch (failure injection).
    struct FailingBackend;
    impl Backend for FailingBackend {
        fn name(&self) -> &str {
            "failing"
        }
        fn max_batch(&self) -> usize {
            4
        }
        fn input_shape(&self) -> (usize, usize, usize) {
            (3, 32, 32)
        }
        fn classes(&self) -> usize {
            10
        }
        fn infer(
            &mut self,
            _images: &bitkernel::tensor::Tensor,
        ) -> anyhow::Result<&bitkernel::tensor::Tensor> {
            anyhow::bail!("injected fault")
        }
    }
    let router = Router::start(
        |_| Ok(Box::new(FailingBackend) as Box<dyn Backend>),
        RouterConfig::default(),
    )
    .unwrap();
    let rx = router.submit(vec![0.0; 3 * 32 * 32]).unwrap();
    // The failure must arrive as a TYPED reply (never a hang, never a
    // bare disconnect): a backend error is not a panic, so the replica
    // survives without a respawn.
    match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
        Err(bitkernel::coordinator::ReplyError::BackendFailed(msg)) => {
            assert!(msg.contains("injected fault"), "{msg}");
        }
        other => panic!("expected BackendFailed, got {other:?}"),
    }
    let snap = router.metrics().snapshot();
    assert_eq!(snap.rejected, 1);
    assert_eq!(snap.completed, 0);
    assert_eq!(snap.panics, 0, "a backend error is not a panic");
    assert_eq!(
        snap.replicas.iter().map(|r| r.restarts).sum::<u64>(),
        0,
        "a backend error must not trigger a respawn"
    );
}

#[test]
fn backend_construction_failure_is_synchronous() {
    let r = Router::start(
        |_| anyhow::bail!("no such model"),
        RouterConfig::default(),
    );
    assert!(r.is_err());
    assert!(format!("{:#}", r.err().unwrap()).contains("no such model"));
}

// --- replica-pool contracts (synthetic engine: no artifacts needed) --------

/// Small but full-architecture synthetic network (same widths layout as
/// `tests/plan_session.rs`).
fn replica_test_plan(max_batch: usize) -> bitkernel::model::Plan {
    synthetic_engine([8, 8, 8, 8, 8, 8, 16, 16, 10], 42)
        .plan(EngineKernel::Xnor(XnorImpl::Auto), max_batch)
        .unwrap()
}

#[test]
fn replies_bit_identical_for_1_and_4_replicas() {
    let plan = replica_test_plan(4);
    let mut rng = Rng::new(7);
    let images: Vec<Vec<f32>> =
        (0..24).map(|_| rng.normal_vec(3 * 32 * 32)).collect();

    let run = |replicas: usize| -> Vec<Vec<f32>> {
        let plan = plan.clone();
        let router = Router::start(
            move |_| {
                Ok(Box::new(NativeBackend::from_plan(&plan))
                    as Box<dyn Backend>)
            },
            RouterConfig {
                queue_cap: 64,
                replicas,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_delay: Duration::from_millis(2),
                },
                ..RouterConfig::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> = images
            .iter()
            .map(|img| router.submit(img.clone()).unwrap())
            .collect();
        let out: Vec<Vec<f32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap().logits)
            .collect();
        router.shutdown();
        out
    };

    let one = run(1);
    let four = run(4);
    assert_eq!(one.len(), four.len());
    for (i, (a, b)) in one.iter().zip(&four).enumerate() {
        assert_eq!(a.len(), b.len());
        for (j, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "image {i} logit {j}: {x} vs {y} — replication must not \
                 change results"
            );
        }
    }
}

#[test]
fn shutdown_drains_every_accepted_request() {
    let router = Router::start(
        |_| Ok(Box::new(MockBackend::new(4, 2)) as Box<dyn Backend>),
        RouterConfig {
            queue_cap: 256,
            replicas: 4,
            batcher: BatcherConfig {
                max_batch: 4,
                max_delay: Duration::from_millis(1),
            },
            ..RouterConfig::default()
        },
    )
    .unwrap();
    let n = 64;
    let rxs: Vec<_> = (0..n)
        .map(|_| router.submit(vec![0.25f32; 3 * 32 * 32]).unwrap())
        .collect();
    let metrics = router.metrics();
    // Drain: every accepted request must still be answered.
    router.shutdown();
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx
            .recv_timeout(Duration::from_secs(5))
            .unwrap_or_else(|e| panic!("request {i} lost in drain: {e}"))
            .unwrap_or_else(|e| panic!("request {i} failed in drain: {e}"));
        assert_eq!(reply.logits.len(), 10);
    }
    let snap = metrics.snapshot();
    assert_eq!(snap.completed, n as u64);
    assert_eq!(
        snap.replicas.iter().map(|r| r.requests).sum::<u64>(),
        n as u64
    );
    assert!(snap.replicas.iter().all(|r| r.inflight == 0));
}

#[test]
fn saturated_admission_queue_surfaces_queue_full() {
    // Slow replicas + tiny admission queue: the bounded per-replica
    // dispatch slots must propagate saturation back to submitters
    // instead of buffering unboundedly.
    let router = Router::start(
        |_| Ok(Box::new(MockBackend::new(1, 30)) as Box<dyn Backend>),
        RouterConfig {
            queue_cap: 2,
            replicas: 2,
            batcher: BatcherConfig {
                max_batch: 1,
                max_delay: Duration::from_millis(1),
            },
            ..RouterConfig::default()
        },
    )
    .unwrap();
    let mut kept = Vec::new();
    let mut rejected = 0u64;
    for _ in 0..40 {
        match router.submit(vec![0.0f32; 3 * 32 * 32]) {
            Ok(rx) => kept.push(rx),
            Err(SubmitError::QueueFull) => rejected += 1,
            Err(e) => panic!("{e}"),
        }
    }
    assert!(rejected > 0, "40 instant submits on 2 slow replicas with \
                           queue_cap=2 must shed load");
    // Every accepted request still completes.
    for rx in kept {
        rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
    }
    let snap = router.metrics().snapshot();
    assert_eq!(snap.rejected, rejected);
    assert_eq!(snap.submitted, 40 - rejected);
    assert_eq!(snap.completed, 40 - rejected);
}

// --- tiny test HTTP client -------------------------------------------------

fn http_get(addr: &std::net::SocketAddr, path: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(stream, "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .unwrap();
    read_response(stream)
}

fn http_post(addr: &std::net::SocketAddr, path: &str, body: &[u8]) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )
    .unwrap();
    stream.write_all(body).unwrap();
    read_response(stream)
}

fn read_response(stream: TcpStream) -> (u16, String) {
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line.split(' ').nth(1).unwrap().parse().unwrap();
    let mut len = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line.to_lowercase().strip_prefix("content-length:") {
            len = v.trim().parse().unwrap();
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}
