//! NetSpec IR tests — these run WITHOUT artifacts, like
//! `plan_session.rs`, so the architecture-generic path is covered in
//! every environment.
//!
//! Pins the four IR contracts of the redesign:
//! 1. BKW1 compatibility: a spec-less (legacy) weight file synthesizes
//!    the exact spec `NetSpec::from_widths` builds, and produces
//!    logits identical to the same tensors with the spec embedded.
//! 2. BKW2 round trip: writer -> reader preserves the spec and the
//!    tensors bit-for-bit, and the reloaded engine's logits match.
//! 3. The acceptance topology (1x28x28 input, 2 convs, 26 classes)
//!    builds, round-trips, plans on xnor/auto, and `Session::run`
//!    matches `forward_reference` bit-exactly.
//! 4. Randomized topologies (non-32 inputs, non-square images, != 10
//!    classes, fc-only nets, non-binarized layers mid-net) stay
//!    bit-identical to the unfused oracle on every Table-2 arm.

use bitkernel::bitops::XnorImpl;
use bitkernel::model::{
    BnnEngine, EngineKernel, LayerSpec, NetSpec, SpecError, WeightFile,
};
use bitkernel::testing::{prop_assert, synthetic_engine_spec,
                         synthetic_weight_file};
use bitkernel::tensor::Tensor;
use bitkernel::utils::Rng;

fn arms() -> [EngineKernel; 4] {
    [
        EngineKernel::Xnor(XnorImpl::Auto),
        EngineKernel::Xnor(XnorImpl::Blocked),
        EngineKernel::Control,
        EngineKernel::Optimized,
    ]
}

fn images_for(spec: &NetSpec, rng: &mut Rng, b: usize) -> Tensor {
    let (c, h, w) = spec.input();
    Tensor::new(vec![b, c, h, w], rng.normal_vec(b * c * h * w))
}

/// Compiled sessions must be bit-identical to the unfused oracle on
/// every arm, across a couple of batch sizes.
fn assert_plan_matches_reference(engine: &BnnEngine, tag: &str) {
    let mut rng = Rng::new(0xBEEF ^ tag.len() as u64);
    for kernel in arms() {
        let mut session = engine
            .plan(kernel, 3)
            .unwrap_or_else(|e| panic!("{tag}: plan failed: {e}"))
            .session();
        for b in [1, 3] {
            let x = images_for(&engine.spec, &mut rng, b);
            let want = engine.forward_reference(&x, kernel);
            let got = session.run(&x);
            assert_eq!(got.shape(), want.shape(), "{tag} {kernel:?} b={b}");
            assert_eq!(
                got.max_abs_diff(&want),
                0.0,
                "{tag} {kernel:?} b={b}: plan diverged from oracle"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// 1. BKW1 -> legacy-spec equivalence
// ---------------------------------------------------------------------------

#[test]
fn bkw1_loads_through_the_synthesized_legacy_spec() {
    const WIDTHS: [u32; 9] = [4, 4, 6, 6, 8, 8, 16, 12, 10];
    let spec = NetSpec::from_widths(&WIDTHS).unwrap();

    // Strip the spec out of a synthetic BKW2 file and add meta.widths:
    // exactly what a legacy exporter would have written.
    let bkw2 = synthetic_weight_file(&spec, 91);
    let mut tensors = std::collections::BTreeMap::new();
    for name in bkw2.names() {
        tensors.insert(name.to_string(), bkw2.get(name).unwrap().clone());
    }
    tensors.insert(
        "meta.widths".to_string(),
        bitkernel::model::WeightTensor::owned(
            bitkernel::model::Dtype::U32,
            vec![9],
            WIDTHS.to_vec(),
        ),
    );
    let bkw1 = WeightFile::from_tensors(tensors);
    assert_eq!(bkw1.version(), 1);

    let legacy = BnnEngine::from_weight_file(&bkw1).unwrap();
    assert_eq!(legacy.spec, spec, "synthesized spec drifted");

    // Same tensors, spec embedded vs synthesized: identical logits.
    let modern = BnnEngine::from_weight_file(&bkw2).unwrap();
    let mut rng = Rng::new(17);
    let x = images_for(&spec, &mut rng, 2);
    for kernel in arms() {
        let a = legacy.forward_reference(&x, kernel);
        let b = modern.forward_reference(&x, kernel);
        assert_eq!(a.max_abs_diff(&b), 0.0, "{kernel:?}");
    }
    assert_plan_matches_reference(&legacy, "bkw1-legacy");
}

// ---------------------------------------------------------------------------
// 2. BKW2 round trip through the writer/reader
// ---------------------------------------------------------------------------

#[test]
fn bkw2_round_trips_spec_and_tensors() {
    let spec = NetSpec::builder((2, 12, 8)) // non-square on purpose
        .conv(5, 3)
        .pool()
        .conv(7, 3)
        .linear(11)
        .linear(4)
        .build()
        .unwrap();
    let wf = synthetic_weight_file(&spec, 55);
    let bytes = wf.to_bytes();
    assert_eq!(&bytes[..4], b"BKW2");

    let back = WeightFile::parse(&bytes[..]).unwrap();
    assert_eq!(back.version(), 2);
    assert_eq!(back.embedded_spec(), Some(&spec));
    assert_eq!(back.len(), wf.len());
    for name in wf.names() {
        let (a, b) = (wf.get(name).unwrap(), back.get(name).unwrap());
        assert_eq!(a.shape, b.shape, "{name}");
        assert_eq!(a.words(), b.words(), "{name}");
    }

    // The reloaded engine computes identical logits.
    let before = BnnEngine::from_weight_file(&wf).unwrap();
    let after = BnnEngine::from_weight_file(&back).unwrap();
    let mut rng = Rng::new(5);
    let x = images_for(&spec, &mut rng, 3);
    for kernel in arms() {
        assert_eq!(
            before
                .forward_reference(&x, kernel)
                .max_abs_diff(&after.forward_reference(&x, kernel)),
            0.0,
            "{kernel:?}"
        );
    }
}

// ---------------------------------------------------------------------------
// 3. The acceptance topology
// ---------------------------------------------------------------------------

#[test]
fn non_cifar_spec_builds_round_trips_and_serves() {
    // 1x28x28 input, 2 convs, 26 classes — nothing CIFAR about it.
    let spec = NetSpec::builder((1, 28, 28))
        .conv(8, 3)
        .pool()
        .conv(12, 3)
        .pool()
        .linear(32)
        .linear(26)
        .build()
        .unwrap();
    assert_eq!(spec.classes(), 26);

    // Round-trip the weights through BKW2 bytes.
    let wf = synthetic_weight_file(&spec, 2026);
    let back = WeightFile::parse(&wf.to_bytes()[..]).unwrap();
    let engine = BnnEngine::from_weight_file(&back).unwrap();
    assert_eq!(engine.spec, spec);

    // Plans on the xnor/auto arm with fully resolved impls...
    let plan = engine.plan(EngineKernel::Xnor(XnorImpl::Auto), 4).unwrap();
    assert_eq!(plan.input_shape(), (1, 28, 28));
    assert_eq!(plan.classes(), 26);
    assert!(plan.xnor_impls().iter().all(|i| *i != XnorImpl::Auto));
    assert!(!plan.buffer_sizes().is_empty());

    // ...and every arm matches the oracle bit-exactly.
    assert_plan_matches_reference(&engine, "acceptance-28x28");

    let mut rng = Rng::new(9);
    let mut session = plan.session();
    let x = images_for(&spec, &mut rng, 4);
    assert_eq!(session.run(&x).shape(), &[4, 26]);
}

// ---------------------------------------------------------------------------
// 4. Randomized topologies
// ---------------------------------------------------------------------------

/// Draw a random-but-valid spec: conv nets over odd input shapes
/// (non-square, non-32) or fc-only nets, with occasional non-binarized
/// layers mid-net to exercise the float paths on the xnor arm.
fn random_spec(rng: &mut Rng) -> NetSpec {
    let fc_only = rng.below(4) == 0;
    if fc_only {
        let c = 1 + rng.below(3);
        let h = 2 + rng.below(5);
        let w = 2 + rng.below(5);
        let mut b = NetSpec::builder((c, h, w));
        b = b.linear(8 + rng.below(40)); // real-input first fc
        if rng.below(2) == 0 {
            // Mid-net non-binarized fc: float gemm on the xnor arm.
            b = b.linear_opts(4 + rng.below(24), false);
        } else {
            b = b.linear(4 + rng.below(24));
        }
        return b.linear(2 + rng.below(25)).build().expect("fc-only spec");
    }
    let c = 1 + rng.below(3);
    // Even dims so pools stay legal; non-square and never 32.
    let h = 2 * (3 + rng.below(4)); // 6..12
    let w = 2 * (3 + rng.below(4));
    let mut b = NetSpec::builder((c, h, w));
    let nconv = 1 + rng.below(3);
    let mut pools = 0;
    for i in 0..nconv {
        let cout = 2 + rng.below(7);
        let ksize = [1, 3][rng.below(2)];
        if i > 0 && rng.below(4) == 0 {
            // Non-binarized conv mid-net: the deferred bn must
            // materialize on the xnor arm.
            b = b.conv_opts(cout, ksize, 1, ksize / 2, false);
        } else {
            b = b.conv(cout, ksize);
        }
        // Pool only while both dims stay even (at most twice: 6/2=3).
        if pools < 1 && rng.below(2) == 0 {
            b = b.pool();
            pools += 1;
        }
    }
    if rng.below(2) == 0 {
        b = b.linear(4 + rng.below(28));
    }
    b.linear(2 + rng.below(25)).build().expect("conv spec")
}

#[test]
fn prop_random_topologies_bit_identical_to_oracle() {
    prop_assert(0xA11CE, 10, |rng, case| {
        let spec = random_spec(rng);
        let engine = synthetic_engine_spec(&spec, 1000 + case as u64);
        for kernel in arms() {
            let mut session = engine
                .plan(kernel, 3)
                .map_err(|e| format!("case {case}: plan: {e}"))?
                .session();
            for b in [1, 3] {
                let x = images_for(&spec, rng, b);
                let want = engine.forward_reference(&x, kernel);
                let got = session.run(&x);
                if got.shape() != want.shape() {
                    return Err(format!(
                        "case {case} {kernel:?} b={b}: shape {:?} vs {:?} \
                         (spec {spec:?})",
                        got.shape(),
                        want.shape()
                    ));
                }
                let diff = got.max_abs_diff(&want);
                if diff != 0.0 {
                    return Err(format!(
                        "case {case} {kernel:?} b={b}: |Δ| = {diff} \
                         (spec {spec:?})"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn fc_only_net_serves_on_every_arm() {
    // Explicit fc-only coverage (the random draw above hits it only
    // probabilistically): raw input rows feed a real fc, then
    // binarized fcs.
    let spec = NetSpec::builder((3, 4, 4))
        .linear(24)
        .linear(16)
        .linear(7)
        .build()
        .unwrap();
    let engine = synthetic_engine_spec(&spec, 321);
    assert_plan_matches_reference(&engine, "fc-only");
}

#[test]
fn binarized_first_conv_is_allowed_and_bit_exact() {
    // Built by hand (the builder keeps the first layer real): a Sign on
    // the raw input feeding a binarized conv — the xnor arm encodes
    // straight from the input tensor.
    let spec = NetSpec::new(
        (2, 6, 6),
        vec![
            LayerSpec::Sign,
            LayerSpec::Conv2d { cout: 5, ksize: 3, stride: 1, pad: 1,
                                binarized: true },
            LayerSpec::BatchNorm,
            LayerSpec::Flatten,
            LayerSpec::Sign,
            LayerSpec::Linear { dout: 4, binarized: true },
            LayerSpec::BatchNorm,
        ],
    )
    .unwrap();
    let engine = synthetic_engine_spec(&spec, 77);
    assert_plan_matches_reference(&engine, "binarized-first-conv");
}

#[test]
fn mixed_binarization_fc_chain_is_bit_exact() {
    // binarized fc -> non-binarized fc -> binarized fc: exercises the
    // xnor arm's BnRows materialization AND the f32 bn_sign_pack
    // re-entry into the packed domain.
    let spec = NetSpec::builder((2, 4, 4))
        .linear(20)
        .linear(12)
        .linear_opts(10, false)
        .linear(5)
        .build()
        .unwrap();
    let engine = synthetic_engine_spec(&spec, 88);
    assert_plan_matches_reference(&engine, "mixed-fc-chain");
}

// ---------------------------------------------------------------------------
// Typed errors at the API edge
// ---------------------------------------------------------------------------

#[test]
fn plan_rejects_zero_batch_with_typed_error() {
    let spec = NetSpec::builder((1, 4, 4)).linear(3).build().unwrap();
    let engine = synthetic_engine_spec(&spec, 1);
    assert!(matches!(
        engine.plan(EngineKernel::Control, 0),
        Err(SpecError::ZeroBatch)
    ));
}

#[test]
fn session_shapes_follow_the_spec() {
    let spec = NetSpec::builder((4, 10, 6))
        .conv(6, 3)
        .linear(9)
        .build()
        .unwrap();
    let engine = synthetic_engine_spec(&spec, 3);
    let plan = engine.plan(EngineKernel::Optimized, 2).unwrap();
    let mut session = plan.session();
    let mut rng = Rng::new(2);
    let x = images_for(&spec, &mut rng, 2);
    assert_eq!(session.run(&x).shape(), &[2, 9]);
    let sig = session.buffer_signature();
    let _ = session.run(&x);
    assert_eq!(session.buffer_signature(), sig,
               "steady-state reallocation on a custom topology");
}
