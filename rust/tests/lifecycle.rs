//! Model-lifecycle tests: mount / reload / unmount over the admin API
//! while `/classify` traffic is in flight.  The invariant under test is
//! the registry's swap discipline — every reply is answered by exactly
//! one weight generation and is bit-identical to that generation's
//! `forward_reference`; a reload or unmount never drops a request or
//! lets one straddle generations.  Everything runs on synthetic BKW
//! files in a temp dir — no artifacts needed.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use bitkernel::bitops::XnorImpl;
use bitkernel::coordinator::{BatcherConfig, RouterConfig};
use bitkernel::data::normalize_batch;
use bitkernel::model::{BnnEngine, EngineKernel, NetSpec, QuantScheme};
use bitkernel::server::{
    http_call, serve, ModelRegistry, RegistryConfig, ServeOptions, Service,
};
use bitkernel::testing::synthetic_weight_file;
use bitkernel::utils::json::Json;

const KERNEL: EngineKernel = EngineKernel::Xnor(XnorImpl::Auto);

// --- fixtures --------------------------------------------------------------

/// Fresh per-test temp dir (removed best-effort on success).
fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("bk-life-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The conv-net under lifecycle churn: 1x4x4 input, 3 classes.
fn spec_conv() -> NetSpec {
    NetSpec::builder((1, 4, 4)).conv(2, 3).linear(3).build().unwrap()
}

/// A heterogeneous second model: 1x5x5 input, 4 classes, fc-only.
fn spec_fc() -> NetSpec {
    NetSpec::builder((1, 5, 5)).linear(4).build().unwrap()
}

/// Write `seed`'s synthetic weights for `spec` as a BKW file.
fn write_model(path: &Path, spec: &NetSpec, seed: u64) {
    synthetic_weight_file(spec, seed).save(path).unwrap();
}

/// Deterministic fake image bytes for `spec`.
fn pixels(spec: &NetSpec, salt: usize) -> Vec<u8> {
    let (c, h, w) = spec.input();
    (0..c * h * w).map(|i| ((i * 31 + salt * 7) % 256) as u8).collect()
}

/// Bit-exactness oracle: the logits generation `seed` must answer
/// `px` with, straight from the unfused reference path.
fn oracle(spec: &NetSpec, seed: u64, px: &[u8]) -> Vec<f32> {
    let (c, h, w) = spec.input();
    let engine =
        BnnEngine::from_weight_file(&synthetic_weight_file(spec, seed))
            .unwrap();
    engine
        .forward_reference(&normalize_batch(px, 1, h, w, c), KERNEL)
        .data()
        .to_vec()
}

fn registry(max_resident: usize) -> Arc<ModelRegistry> {
    ModelRegistry::new(RegistryConfig {
        kernel: KERNEL,
        max_batch: 4,
        router: RouterConfig {
            queue_cap: 256,
            replicas: 2,
            batcher: BatcherConfig {
                max_batch: 4,
                max_delay: Duration::from_millis(1),
            },
            ..RouterConfig::default()
        },
        max_resident,
    })
}

// --- tiny server + client harness ------------------------------------------

struct TestServer {
    addr: String,
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<()>,
}

/// Boot an admin-enabled server over `registry` on a free port.
fn boot(registry: Arc<ModelRegistry>) -> TestServer {
    let service =
        Arc::new(Service::with_registry(registry, None, true));
    let stop = Arc::new(AtomicBool::new(false));
    let (ready_tx, ready_rx) = std::sync::mpsc::channel();
    let stop2 = Arc::clone(&stop);
    let handle = std::thread::spawn(move || {
        serve(
            service,
            &ServeOptions {
                addr: "127.0.0.1:0".into(),
                threads: 4,
                ..ServeOptions::default()
            },
            stop2,
            Some(ready_tx),
        )
        .unwrap();
    });
    let addr = ready_rx.recv_timeout(Duration::from_secs(10)).unwrap();
    TestServer { addr: addr.to_string(), stop, handle }
}

impl TestServer {
    fn shutdown(self) {
        self.stop.store(true, Ordering::Relaxed);
        self.handle.join().unwrap();
    }
}

fn json(body: &[u8]) -> Json {
    Json::parse(std::str::from_utf8(body).unwrap()).unwrap()
}

/// `POST /models` body for one mount.
fn mount_body(name: &str, path: &Path, lazy: bool) -> Vec<u8> {
    Json::obj(vec![
        ("name", Json::Str(name.to_string())),
        ("path", Json::Str(path.display().to_string())),
        ("lazy", Json::Bool(lazy)),
    ])
    .to_string()
    .into_bytes()
}

/// Mount over the admin API with `?wait=1`, returning the settled
/// descriptor.
fn mount_wait(addr: &str, name: &str, path: &Path, lazy: bool) -> Json {
    let (status, body) = http_call(
        addr,
        "POST",
        "/models?wait=1",
        &mount_body(name, path, lazy),
    )
    .unwrap();
    assert_eq!(status, 201, "{}", String::from_utf8_lossy(&body));
    json(&body)
}

/// One classify call; returns `(status, body)`.
fn classify(addr: &str, model: &str, px: &[u8]) -> (u16, Vec<u8>) {
    http_call(addr, "POST", &format!("/classify?model={model}"), px)
        .unwrap()
}

/// Parse a classify reply into `(generation, logits)`.
fn reply_logits(body: &[u8]) -> (u64, Vec<f32>) {
    let v = json(body);
    let generation =
        v.get("generation").unwrap().as_f64().unwrap() as u64;
    let logits = v
        .get("logits")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|j| j.as_f64().unwrap() as f32)
        .collect();
    (generation, logits)
}

fn assert_bit_identical(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: logit count");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{ctx}: logit {i} ({g} vs {w}) — replies must be \
             bit-identical to their generation's forward_reference"
        );
    }
}

/// Poll `GET /models/{name}` until `pred` holds on the descriptor.
fn poll_status(addr: &str, name: &str, what: &str,
               pred: impl Fn(&Json) -> bool) -> Json {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let (status, body) =
            http_call(addr, "GET", &format!("/models/{name}"), b"")
                .unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let v = json(&body);
        if pred(&v) {
            return v;
        }
        assert!(
            Instant::now() < deadline,
            "timed out waiting for {name}: {what} (last: {v})"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

// --- scenarios -------------------------------------------------------------

#[test]
fn admin_mount_reload_unmount_roundtrip() {
    let dir = temp_dir("roundtrip");
    let conv = spec_conv();
    let fc = spec_fc();
    let conv_path = dir.join("conv.bkw");
    write_model(&conv_path, &conv, 1);
    let srv = boot(registry(0));
    let addr = &srv.addr;

    // Mount synchronously: 201 with the full shape contract.
    let st = mount_wait(addr, "conv", &conv_path, false);
    assert_eq!(st.get("state").unwrap().as_str(), Some("ready"));
    assert_eq!(st.get("resident").unwrap().as_bool(), Some(true));
    assert_eq!(st.get("reloadable").unwrap().as_bool(), Some(true));
    assert_eq!(st.get("image_bytes").unwrap().as_usize(), Some(16));
    assert_eq!(st.get("classes").unwrap().as_usize(), Some(3));
    let g1 = st.get("generation").unwrap().as_f64().unwrap() as u64;
    assert!(g1 >= 1);

    // Serve generation 1 bit-identically.
    let px = pixels(&conv, 0);
    let (status, body) = classify(addr, "conv", &px);
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let (gen, logits) = reply_logits(&body);
    assert_eq!(gen, g1);
    assert_bit_identical(&logits, &oracle(&conv, 1, &px), "gen 1");

    // Reload from new on-disk weights: new generation, new logits.
    write_model(&conv_path, &conv, 2);
    let (status, body) =
        http_call(addr, "PUT", "/models/conv?wait=1", b"").unwrap();
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let g2 = json(&body).get("generation").unwrap().as_f64().unwrap()
        as u64;
    assert!(g2 > g1, "reload must mint a new generation");
    let (status, body) = classify(addr, "conv", &px);
    assert_eq!(status, 200);
    let (gen, logits) = reply_logits(&body);
    assert_eq!(gen, g2);
    assert_bit_identical(&logits, &oracle(&conv, 2, &px), "gen 2");

    // Async mount of a second (heterogeneous) model: 202, then poll
    // GET /models/{name} to readiness.
    let fc_path = dir.join("fc.bkw");
    write_model(&fc_path, &fc, 9);
    let (status, body) = http_call(
        addr,
        "POST",
        "/models",
        &mount_body("fc", &fc_path, false),
    )
    .unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    poll_status(addr, "fc", "ready", |v| {
        v.get("state").unwrap().as_str() == Some("ready")
    });
    let px_fc = pixels(&fc, 3);
    let (status, body) = classify(addr, "fc", &px_fc);
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let (_, logits) = reply_logits(&body);
    assert_bit_identical(&logits, &oracle(&fc, 9, &px_fc), "fc");

    // Typed admin errors: duplicate mount 409, unknown reload 404,
    // bad name 400.
    let (status, _) = http_call(
        addr,
        "POST",
        "/models?wait=1",
        &mount_body("conv", &conv_path, false),
    )
    .unwrap();
    assert_eq!(status, 409);
    let (status, _) =
        http_call(addr, "PUT", "/models/ghost?wait=1", b"").unwrap();
    assert_eq!(status, 404);
    let (status, _) = http_call(
        addr,
        "POST",
        "/models?wait=1",
        &mount_body("no/slash", &conv_path, false),
    )
    .unwrap();
    assert_eq!(status, 400);

    // A mount from a bad path fails synchronously (500) and is
    // visible as `failed` until unmounted.
    let (status, body) = http_call(
        addr,
        "POST",
        "/models?wait=1",
        &mount_body("broken", &dir.join("missing.bkw"), false),
    )
    .unwrap();
    assert_eq!(status, 500, "{}", String::from_utf8_lossy(&body));
    let st = poll_status(addr, "broken", "failed", |v| {
        v.get("state").unwrap().as_str() == Some("failed")
    });
    assert!(st.get("error").unwrap().as_str().is_some());
    let (status, body) = classify(addr, "broken", &px);
    assert_eq!(status, 503, "{}", String::from_utf8_lossy(&body));
    let (status, _) =
        http_call(addr, "DELETE", "/models/broken", b"").unwrap();
    assert_eq!(status, 200);

    // Unmount: 200, then every route 404s the name.
    let (status, body) =
        http_call(addr, "DELETE", "/models/conv", b"").unwrap();
    assert_eq!(status, 200);
    assert_eq!(json(&body).get("unmounted").unwrap().as_str(),
               Some("conv"));
    let (status, _) =
        http_call(addr, "GET", "/models/conv", b"").unwrap();
    assert_eq!(status, 404);
    let (status, _) = classify(addr, "conv", &px);
    assert_eq!(status, 404);
    let (status, body) = http_call(addr, "GET", "/models", b"").unwrap();
    assert_eq!(status, 200);
    let names: Vec<String> = json(&body)
        .as_arr()
        .unwrap()
        .iter()
        .map(|m| m.get("name").unwrap().as_str().unwrap().to_string())
        .collect();
    assert_eq!(names, vec!["fc".to_string()]);

    srv.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn reload_under_hammer_is_lossless_and_generation_exact() {
    let dir = temp_dir("hammer");
    let conv = spec_conv();
    let fc = spec_fc();
    let hot_path = dir.join("hot.bkw");
    let side_path = dir.join("side.bkw");
    write_model(&hot_path, &conv, 100);
    write_model(&side_path, &fc, 200);
    let srv = boot(registry(0));
    let addr = srv.addr.clone();

    // Two models mounted over the admin API; "hot" gets churned.
    let st = mount_wait(&addr, "hot", &hot_path, false);
    let g0 = st.get("generation").unwrap().as_f64().unwrap() as u64;
    mount_wait(&addr, "side", &side_path, false);

    // generation -> the seed whose weights that generation serves.
    let mut gen_seed = std::collections::BTreeMap::new();
    gen_seed.insert(g0, 100u64);

    // Hammer /classify?model=hot from 4 closed-loop clients.  EVERY
    // reply must be a 200 — a reload may never drop or bounce one.
    let stop = Arc::new(AtomicBool::new(false));
    let mut clients = Vec::new();
    for tid in 0..4usize {
        let addr = addr.clone();
        let stop = Arc::clone(&stop);
        let conv = conv.clone();
        clients.push(std::thread::spawn(move || {
            let mut replies = Vec::new();
            let mut n = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let salt = (tid * 3 + n) % 4;
                n += 1;
                let px = pixels(&conv, salt);
                let (status, body) = classify(&addr, "hot", &px);
                assert_eq!(
                    status, 200,
                    "reload dropped a request: {}",
                    String::from_utf8_lossy(&body)
                );
                let (generation, logits) = reply_logits(&body);
                replies.push((generation, salt, logits));
            }
            replies
        }));
    }

    // Reload "hot" five times from fresh on-disk weights while the
    // hammer runs; record which seed each generation serves.
    for i in 1..=5u64 {
        let seed = 100 + i;
        write_model(&hot_path, &conv, seed);
        let (status, body) =
            http_call(&addr, "PUT", "/models/hot?wait=1", b"").unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let g = json(&body).get("generation").unwrap().as_f64().unwrap()
            as u64;
        gen_seed.insert(g, seed);
        // The untouched model keeps serving its own weights throughout.
        let px = pixels(&fc, 1);
        let (status, body) = classify(&addr, "side", &px);
        assert_eq!(status, 200);
        let (_, logits) = reply_logits(&body);
        assert_bit_identical(&logits, &oracle(&fc, 200, &px), "side");
        std::thread::sleep(Duration::from_millis(20));
    }
    stop.store(true, Ordering::Relaxed);
    let replies: Vec<(u64, usize, Vec<f32>)> = clients
        .into_iter()
        .flat_map(|c| c.join().unwrap())
        .collect();
    assert!(!replies.is_empty());

    // Every reply came from a known generation and is bit-identical
    // to THAT generation's reference — no torn or mixed-weight reply.
    let mut oracles: std::collections::BTreeMap<(u64, usize), Vec<f32>> =
        std::collections::BTreeMap::new();
    let mut gens_seen = std::collections::BTreeSet::new();
    for (generation, salt, logits) in &replies {
        let seed = *gen_seed.get(generation).unwrap_or_else(|| {
            panic!("reply from unknown generation {generation}")
        });
        gens_seen.insert(*generation);
        let want = oracles
            .entry((seed, *salt))
            .or_insert_with(|| oracle(&conv, seed, &pixels(&conv, *salt)));
        assert_bit_identical(
            logits,
            want,
            &format!("gen {generation} (seed {seed}) salt {salt}"),
        );
    }
    println!(
        "hammer: {} replies across generations {:?}",
        replies.len(),
        gens_seen
    );

    srv.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn scheme_reload_under_traffic_is_lossless_and_scheme_exact() {
    // Hot-reload a mounted model across QUANTIZATION SCHEMES
    // (sign_sign -> xnor_alpha -> back) under closed-loop traffic.
    // Same topology, different lowering: every reply must still be
    // answered by exactly one generation, bit-identical to THAT
    // generation's scheme-aware forward_reference, with zero drops,
    // and /models must report the live scheme after each swap.
    let dir = temp_dir("scheme");
    let sign = spec_conv();
    let alpha = NetSpec::builder((1, 4, 4))
        .conv(2, 3)
        .linear(3)
        .scheme(QuantScheme::XnorAlpha)
        .build()
        .unwrap();
    let path = dir.join("s.bkw");
    write_model(&path, &sign, 300);
    let srv = boot(registry(0));
    let addr = srv.addr.clone();

    let st = mount_wait(&addr, "s", &path, false);
    assert_eq!(st.get("scheme").unwrap().as_str(), Some("sign_sign"));
    let g0 = st.get("generation").unwrap().as_f64().unwrap() as u64;

    // generation -> (spec-with-scheme, seed) it serves.
    let mut gen_model = std::collections::BTreeMap::new();
    gen_model.insert(g0, (sign.clone(), 300u64));

    let stop = Arc::new(AtomicBool::new(false));
    let mut clients = Vec::new();
    for tid in 0..3usize {
        let addr = addr.clone();
        let stop = Arc::clone(&stop);
        let sign = sign.clone();
        clients.push(std::thread::spawn(move || {
            let mut replies = Vec::new();
            let mut n = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let salt = (tid * 5 + n) % 4;
                n += 1;
                // Both schemes share the input contract, so the same
                // pixels are valid across every generation.
                let px = pixels(&sign, salt);
                let (status, body) = classify(&addr, "s", &px);
                assert_eq!(
                    status, 200,
                    "scheme reload dropped a request: {}",
                    String::from_utf8_lossy(&body)
                );
                let (generation, logits) = reply_logits(&body);
                replies.push((generation, salt, logits));
            }
            replies
        }));
    }

    // Swap scheme on every reload while the hammer runs.
    for (i, spec) in
        [(1u64, &alpha), (2, &sign), (3, &alpha)]
    {
        let seed = 300 + i;
        write_model(&path, spec, seed);
        let (status, body) =
            http_call(&addr, "PUT", "/models/s?wait=1", b"").unwrap();
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
        let g = json(&body).get("generation").unwrap().as_f64().unwrap()
            as u64;
        gen_model.insert(g, (spec.clone(), seed));
        let st = poll_status(&addr, "s", "scheme swap", |v| {
            v.get("generation").unwrap().as_f64().unwrap() as u64 == g
        });
        assert_eq!(
            st.get("scheme").unwrap().as_str(),
            Some(spec.scheme().name()),
            "status must report the live generation's scheme"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    stop.store(true, Ordering::Relaxed);
    let replies: Vec<(u64, usize, Vec<f32>)> = clients
        .into_iter()
        .flat_map(|c| c.join().unwrap())
        .collect();
    assert!(!replies.is_empty());

    // Every reply is bit-identical to ITS generation's scheme-aware
    // oracle — no reply computed under a half-swapped scheme.
    let mut oracles: std::collections::BTreeMap<(u64, usize), Vec<f32>> =
        std::collections::BTreeMap::new();
    let mut gens_seen = std::collections::BTreeSet::new();
    for (generation, salt, logits) in &replies {
        let (spec, seed) = gen_model.get(generation).unwrap_or_else(|| {
            panic!("reply from unknown generation {generation}")
        });
        gens_seen.insert(*generation);
        let want = oracles
            .entry((*seed, *salt))
            .or_insert_with(|| oracle(spec, *seed, &pixels(spec, *salt)));
        assert_bit_identical(
            logits,
            want,
            &format!(
                "gen {generation} ({} seed {seed}) salt {salt}",
                spec.scheme().name()
            ),
        );
    }
    println!(
        "scheme hammer: {} replies across generations {:?}",
        replies.len(),
        gens_seen
    );

    srv.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn unmount_under_traffic_drains_cleanly_to_404() {
    let dir = temp_dir("unmount");
    let conv = spec_conv();
    let path = dir.join("u.bkw");
    write_model(&path, &conv, 7);
    let srv = boot(registry(0));
    let addr = srv.addr.clone();
    mount_wait(&addr, "u", &path, false);

    // Clients tolerate exactly two outcomes: a bit-identical 200 (the
    // request held the router before the unmount) or a clean 404
    // (admitted after) — never a 5xx, a hang, or wrong logits.
    let stop = Arc::new(AtomicBool::new(false));
    let mut clients = Vec::new();
    for tid in 0..3usize {
        let addr = addr.clone();
        let stop = Arc::clone(&stop);
        let conv = conv.clone();
        clients.push(std::thread::spawn(move || {
            let (mut ok, mut gone) = (0usize, 0usize);
            let mut n = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let salt = (tid + n) % 3;
                n += 1;
                let px = pixels(&conv, salt);
                let (status, body) = classify(&addr, "u", &px);
                match status {
                    200 => {
                        let (_, logits) = reply_logits(&body);
                        assert_bit_identical(
                            &logits,
                            &oracle(&conv, 7, &px),
                            "pre-unmount",
                        );
                        ok += 1;
                    }
                    404 => gone += 1,
                    other => panic!(
                        "unmount produced HTTP {other}: {}",
                        String::from_utf8_lossy(&body)
                    ),
                }
            }
            (ok, gone)
        }));
    }

    std::thread::sleep(Duration::from_millis(150));
    let (status, _) =
        http_call(&addr, "DELETE", "/models/u", b"").unwrap();
    assert_eq!(status, 200);
    // New lookups 404 immediately after the map removal.
    let (status, _) = classify(&addr, "u", &pixels(&conv, 0));
    assert_eq!(status, 404);
    std::thread::sleep(Duration::from_millis(50));
    stop.store(true, Ordering::Relaxed);
    let mut ok = 0usize;
    for c in clients {
        let (o, _gone) = c.join().unwrap();
        ok += o;
    }
    assert!(ok > 0, "no traffic was served before the unmount");

    srv.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lazy_mount_stays_cold_until_first_request_same_generation() {
    let dir = temp_dir("lazy");
    let conv = spec_conv();
    let path = dir.join("l.bkw");
    write_model(&path, &conv, 42);
    let srv = boot(registry(0));
    let addr = &srv.addr;

    // Lazy mount: weights mapped, contract known, NO pipeline yet.
    let st = mount_wait(addr, "l", &path, true);
    assert_eq!(st.get("state").unwrap().as_str(), Some("ready"));
    assert_eq!(st.get("resident").unwrap().as_bool(), Some(false));
    assert_eq!(st.get("image_bytes").unwrap().as_usize(), Some(16));
    let g = st.get("generation").unwrap().as_f64().unwrap() as u64;
    assert!(g >= 1, "a lazy mount still reads weights from disk");

    // First request compiles in-line; the generation does NOT change
    // (same mapped weights, same logits).
    let px = pixels(&conv, 1);
    let (status, body) = classify(addr, "l", &px);
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let (gen, logits) = reply_logits(&body);
    assert_eq!(gen, g, "a lazy build is not a new generation");
    assert_bit_identical(&logits, &oracle(&conv, 42, &px), "lazy");
    let st = poll_status(addr, "l", "resident", |v| {
        v.get("resident").unwrap().as_bool() == Some(true)
    });
    assert_eq!(st.get("generation").unwrap().as_f64().unwrap() as u64, g);

    srv.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lru_demotion_keeps_models_servable_and_metrics_gc_on_unmount() {
    let dir = temp_dir("lru");
    let conv = spec_conv();
    let fc = spec_fc();
    let a_path = dir.join("a.bkw");
    let b_path = dir.join("b.bkw");
    write_model(&a_path, &conv, 3);
    write_model(&b_path, &fc, 4);
    // At most ONE resident pipeline: mounting "b" demotes "a" to cold.
    let srv = boot(registry(1));
    let addr = &srv.addr;
    let st = mount_wait(addr, "a", &a_path, false);
    let ga = st.get("generation").unwrap().as_f64().unwrap() as u64;
    mount_wait(addr, "b", &b_path, false);
    poll_status(addr, "a", "demoted", |v| {
        v.get("resident").unwrap().as_bool() == Some(false)
            && v.get("state").unwrap().as_str() == Some("ready")
    });

    // The demoted model rebuilds on demand — same generation, same
    // bits — and its rebuild in turn demotes "b".
    let px = pixels(&conv, 2);
    let (status, body) = classify(addr, "a", &px);
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let (gen, logits) = reply_logits(&body);
    assert_eq!(gen, ga, "an LRU rebuild is not a new generation");
    assert_bit_identical(&logits, &oracle(&conv, 3, &px), "rebuilt a");
    poll_status(addr, "b", "demoted", |v| {
        v.get("resident").unwrap().as_bool() == Some(false)
    });

    // Metrics cover exactly the mounted set, and GC with it.
    let (status, body) = http_call(addr, "GET", "/metrics", b"").unwrap();
    assert_eq!(status, 200);
    let metrics = String::from_utf8(body).unwrap();
    assert!(metrics.contains("bitkernel_models_mounted 2"), "{metrics}");
    assert!(metrics.contains("bitkernel_mount_epoch{model=\"a\"}"),
            "{metrics}");
    assert!(metrics.contains("bitkernel_mount_epoch{model=\"b\"}"),
            "{metrics}");
    for name in ["a", "b"] {
        let (status, _) = http_call(
            addr, "DELETE", &format!("/models/{name}"), b"",
        )
        .unwrap();
        assert_eq!(status, 200);
    }
    let (_, body) = http_call(addr, "GET", "/metrics", b"").unwrap();
    let metrics = String::from_utf8(body).unwrap();
    assert!(metrics.contains("bitkernel_models_mounted 0"), "{metrics}");
    assert!(!metrics.contains("model=\"a\""),
            "unmounted series must vanish: {metrics}");
    assert!(!metrics.contains("model=\"b\""),
            "unmounted series must vanish: {metrics}");

    srv.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
