//! Property tests (testing::prop harness) on the bit-level invariants
//! the paper's whole speedup argument rests on.

use bitkernel::bitops::{pack_rows, ternary_gemm, xnor_gemm, XnorImpl};
use bitkernel::gemm::{gemm_naive, gemm_blocked};
use bitkernel::nn::{bn_sign_pack_rows_i32_alpha, im2col_t, out_hw};
use bitkernel::tensor::{PackedMatrix, Tensor};
use bitkernel::testing::{dim, prop_assert};
use bitkernel::utils::Rng;

/// Dense ±1 dot product in i32 (exact).
fn dense_dot(a: &[f32], b: &[f32]) -> i32 {
    a.iter().zip(b).map(|(x, y)| (x * y) as i32).sum()
}

#[test]
fn prop_pack_roundtrip() {
    prop_assert(11, 60, |rng: &mut Rng, _| {
        let rows = dim(rng, 12);
        let k = dim(rng, 150);
        let vals = rng.normal_vec(rows * k);
        let p = pack_rows(&vals, rows, k);
        for r in 0..rows {
            for i in 0..k {
                let want = if vals[r * k + i] >= 0.0 { 1.0 } else { -1.0 };
                if p.get(r, i) != want {
                    return Err(format!("({r},{i}): {} vs {want}",
                                       p.get(r, i)));
                }
            }
        }
        Ok(())
    });
}

/// Every impl a fuzz case may pick: the full single-threaded ladder
/// (incl. the AVX-512 and AVX2 SIMD tiers — `Avx512` is in
/// `ALL_SINGLE` and detection-gates internally, so on AVX-512 hosts
/// the 512-bit tile kernels join every cross-check below and elsewhere
/// its fallback is re-verified), the shape-resolved `Auto`, and 2-D
/// tiled threading at two widths.
fn fuzz_impls() -> Vec<XnorImpl> {
    let mut v = XnorImpl::ALL_SINGLE.to_vec();
    v.push(XnorImpl::Auto);
    v.push(XnorImpl::Threaded(2));
    v.push(XnorImpl::Threaded(5));
    v
}

#[test]
fn fuzz_set_includes_the_avx512_arm() {
    // Guards the coverage above: if a refactor ever drops Avx512 from
    // ALL_SINGLE, the differential fuzz would silently stop testing
    // the 512-bit tier.
    assert!(fuzz_impls().contains(&XnorImpl::Avx512));
}

#[test]
fn prop_xnor_gemm_equals_dense_all_impls() {
    let impls = fuzz_impls();
    prop_assert(12, 60, |rng: &mut Rng, case| {
        let d = dim(rng, 10);
        let k = dim(rng, 200);
        let n = dim(rng, 10);
        let wm = rng.sign_vec(d * k);
        let xm = rng.sign_vec(n * k);
        let w = pack_rows(&wm, d, k);
        let x = pack_rows(&xm, n, k);
        let imp = impls[case % impls.len()];
        let mut got = vec![0i32; d * n];
        xnor_gemm(&w, &x, &mut got, imp);
        for i in 0..d {
            for j in 0..n {
                let want = dense_dot(&wm[i * k..(i + 1) * k],
                                     &xm[j * k..(j + 1) * k]);
                if got[i * n + j] != want {
                    return Err(format!(
                        "{imp:?} ({i},{j}) d={d} k={k} n={n}: {} vs {want}",
                        got[i * n + j]
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_all_impls_bit_identical_to_scalar_on_ragged_shapes() {
    // Differential fuzz: every impl (incl. Auto and the SIMD tiers)
    // against the Scalar oracle over the ragged reduction lengths that
    // stress word/lane tails, with odd D/N so the 4-column and 2-row
    // blocking paths all hit their remainders.
    const KS: [usize; 6] = [1, 31, 32, 33, 255, 257];
    let impls = fuzz_impls();
    prop_assert(16, 48, |rng: &mut Rng, case| {
        let k = KS[case % KS.len()];
        let d = 1 + 2 * rng.below(6); // odd in 1..=11
        let n = 1 + 2 * rng.below(6);
        let w = pack_rows(&rng.sign_vec(d * k), d, k);
        let x = pack_rows(&rng.sign_vec(n * k), n, k);
        let mut want = vec![0i32; d * n];
        xnor_gemm(&w, &x, &mut want, XnorImpl::Scalar);
        for &imp in &impls {
            let mut got = vec![i32::MIN; d * n];
            xnor_gemm(&w, &x, &mut got, imp);
            if got != want {
                return Err(format!(
                    "{imp:?} diverges from Scalar at d={d} k={k} n={n}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ternary_gemm_equals_dense_and_scalar_on_ragged_shapes() {
    // Two-plane ternary popcount: dense {-1,0,+1}·{-1,+1} ground truth
    // on the Scalar arm, then every impl differentially against Scalar
    // — same ragged K grid and odd D/N as the binary fuzz above.
    const KS: [usize; 6] = [1, 31, 32, 33, 255, 257];
    let impls = fuzz_impls();
    prop_assert(17, 48, |rng: &mut Rng, case| {
        let k = KS[case % KS.len()];
        let d = 1 + 2 * rng.below(5); // odd in 1..=9
        let n = 1 + 2 * rng.below(5);
        let wm: Vec<f32> =
            (0..d * k).map(|_| rng.below(3) as f32 - 1.0).collect();
        let xm = rng.sign_vec(n * k);
        // The two planes exactly as model/bnn.rs packs them: pos is
        // +1 where w > 0, neg is +1 where w < 0 (zeros hit neither).
        let plane = |positive: bool| {
            let vals: Vec<f32> = wm
                .iter()
                .map(|&v| {
                    let hit = if positive { v > 0.0 } else { v < 0.0 };
                    if hit { 1.0 } else { -1.0 }
                })
                .collect();
            pack_rows(&vals, d, k)
        };
        let (pos, neg) = (plane(true), plane(false));
        let x = pack_rows(&xm, n, k);
        let mut want = vec![0i32; d * n];
        let mut scratch = vec![0i32; d * n];
        ternary_gemm(&pos, &neg, &x, &mut want, &mut scratch,
                     XnorImpl::Scalar);
        for i in 0..d {
            for j in 0..n {
                let dot: i32 = wm[i * k..(i + 1) * k]
                    .iter()
                    .zip(&xm[j * k..(j + 1) * k])
                    .map(|(w, x)| (w * x) as i32)
                    .sum();
                if want[i * n + j] != dot {
                    return Err(format!(
                        "Scalar ({i},{j}) d={d} k={k} n={n}: {} vs {dot}",
                        want[i * n + j]
                    ));
                }
            }
        }
        for &imp in &impls {
            let mut got = vec![i32::MIN; d * n];
            ternary_gemm(&pos, &neg, &x, &mut got, &mut scratch, imp);
            if got != want {
                return Err(format!(
                    "{imp:?} ternary diverges from Scalar at d={d} k={k} \
                     n={n}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_alpha_bn_sign_pack_matches_unfused_rows() {
    // The α-scaled re-encode epilogue: the fused word-building path
    // (BitWriter, incl. word-boundary tails at d = 255/257) must place
    // exactly the bit sign(a*(α*g)+b) computes elementwise.
    const DS: [usize; 6] = [1, 31, 32, 33, 255, 257];
    prop_assert(18, 36, |rng: &mut Rng, case| {
        let d = DS[case % DS.len()];
        let b = 1 + 2 * rng.below(4); // odd in 1..=7
        let gemm: Vec<i32> =
            (0..d * b).map(|_| rng.below(201) as i32 - 100).collect();
        let alpha: Vec<f32> =
            (0..d).map(|_| rng.uniform(0.25, 4.0)).collect();
        let a = rng.normal_vec(d);
        let bias = rng.normal_vec(d);
        let mut fused = PackedMatrix::zeros(b, d);
        bn_sign_pack_rows_i32_alpha(&gemm, d, b, &alpha, &a, &bias,
                                    &mut fused);
        for bi in 0..b {
            for di in 0..d {
                let v = a[di] * (alpha[di] * gemm[di * b + bi] as f32)
                    + bias[di];
                let want = if v >= 0.0 { 1.0 } else { -1.0 };
                if fused.get(bi, di) != want {
                    return Err(format!(
                        "(b={bi},d={di}) of d={d} b={b}: packed {} vs \
                         sign({v})",
                        fused.get(bi, di)
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_float_gemms_agree_on_signs() {
    // On ±1 inputs all float kernels and the xnor kernel are EXACTLY equal.
    prop_assert(13, 30, |rng: &mut Rng, _| {
        let d = dim(rng, 8);
        let k = dim(rng, 120);
        let n = dim(rng, 8);
        let a = rng.sign_vec(d * k);
        let bt = rng.sign_vec(n * k);
        let mut naive = vec![0.0f32; d * n];
        let mut blocked = vec![0.0f32; d * n];
        gemm_naive(&a, &bt, &mut naive, d, k, n);
        gemm_blocked(&a, &bt, &mut blocked, d, k, n);
        if naive != blocked {
            return Err("naive != blocked".into());
        }
        let mut packed = vec![0i32; d * n];
        xnor_gemm(&pack_rows(&a, d, k), &pack_rows(&bt, n, k), &mut packed,
                  XnorImpl::Blocked);
        for (f, i) in naive.iter().zip(&packed) {
            if *f as i32 != *i {
                return Err(format!("float {f} vs packed {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_im2col_row_matches_patch() {
    // Every im2col row must equal the brute-force extracted patch.
    prop_assert(14, 25, |rng: &mut Rng, _| {
        let b = dim(rng, 2);
        let c = dim(rng, 3);
        let h = 4 + rng.below(6);
        let w = 4 + rng.below(6);
        let ks = [1, 3, 5][rng.below(3)];
        let pad = rng.below(ks.min(3));
        let stride = 1 + rng.below(2);
        if h + 2 * pad < ks || w + 2 * pad < ks {
            return Ok(());
        }
        let x = Tensor::new(vec![b, c, h, w], rng.normal_vec(b * c * h * w));
        let cols = im2col_t(&x, ks, ks, stride, pad);
        let (oh, ow) = out_hw(h, w, ks, ks, stride, pad);
        // spot-check a few random rows
        for _ in 0..5 {
            let bi = rng.below(b);
            let oy = rng.below(oh);
            let ox = rng.below(ow);
            let row = cols.row((bi * oh + oy) * ow + ox);
            for _ in 0..5 {
                let ci = rng.below(c);
                let dy = rng.below(ks);
                let dx = rng.below(ks);
                let iy = (oy * stride + dy) as isize - pad as isize;
                let ix = (ox * stride + dx) as isize - pad as isize;
                let want = if iy >= 0 && iy < h as isize && ix >= 0
                    && ix < w as isize
                {
                    x.data()[((bi * c + ci) * h + iy as usize) * w
                        + ix as usize]
                } else {
                    0.0
                };
                let got = row[(ci * ks + dy) * ks + dx];
                if got != want {
                    return Err(format!(
                        "b{bi} c{ci} oy{oy} ox{ox} dy{dy} dx{dx}: {got} vs {want}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_parity_invariant() {
    // <w, x> over k ±1 terms always has k's parity — a cheap whole-kernel
    // sanity invariant the paper's formula must satisfy.
    prop_assert(15, 40, |rng: &mut Rng, _| {
        let k = dim(rng, 257);
        let w = pack_rows(&rng.sign_vec(3 * k), 3, k);
        let x = pack_rows(&rng.sign_vec(4 * k), 4, k);
        let mut out = vec![0i32; 12];
        xnor_gemm(&w, &x, &mut out, XnorImpl::Word64);
        for &v in &out {
            if v.rem_euclid(2) != (k % 2) as i32 {
                return Err(format!("k={k} value {v}"));
            }
            if v.abs() > k as i32 {
                return Err(format!("k={k} out of range {v}"));
            }
        }
        Ok(())
    });
}
